import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # LICM would hoist the CPU backend's f32 upcast of bf16 dot operands out
    # of the layer scan, counting an f32 copy of every layer's weights/cache
    # as simultaneously-live temp memory.  A TPU backend consumes bf16
    # directly (MXU); disabling while-loop LICM keeps the CPU dry-run's
    # memory_analysis() representative.  See EXPERIMENTS.md §Dry-run.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell: jit the appropriate
step function with production in/out shardings, ``.lower()`` on
ShapeDtypeStruct inputs, ``.compile()``, and record
``memory_analysis()`` / ``cost_analysis()`` / collective-bytes (parsed from
the compiled HLO) into reports/dryrun/*.json.  Resumable per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k [--multi-pod] [--force]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse          # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import SHAPES                      # noqa: E402
from repro.launch import sharding as shd                   # noqa: E402
from repro.launch import specs as specs_lib                # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.models import registry                          # noqa: E402
from repro.models.layers import Ctx                        # noqa: E402
from repro.roofline.hlo_costs import parse_hlo_costs  # noqa: E402
from repro.train import optimizer as opt_lib               # noqa: E402
from repro.train.train_state import make_train_step        # noqa: E402

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def runnable(cfg, shape_name: str) -> bool:
    """DESIGN.md §Arch-applicability: long_500k needs sub-quadratic mixing."""
    if shape_name == "long_500k":
        return cfg.sub_quadratic
    return True


def _moment_dtype(cfg) -> str:
    # bf16 moments for the largest models (see optimizer.py docstring)
    return "bfloat16" if cfg.param_count() > 1e11 else "float32"


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg, mod = registry.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    if shape.kind == "train":
        rules = dict(shd.TRAIN_RULES)
    else:
        rules = dict(shd.SERVE_RULES)
    ctx = Ctx(mesh, rules)

    psp = specs_lib.param_specs(cfg, mod, mesh, rules, tp)
    bsp = specs_lib.batch_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        mdt = _moment_dtype(cfg)
        ocfg = opt_lib.OptConfig(state_dtype=mdt)
        osp = specs_lib.opt_specs(
            cfg, mod, mesh, rules, tp,
            jnp.bfloat16 if mdt == "bfloat16" else jnp.float32)
        step = make_train_step(mod, cfg, ocfg, ctx)
        fn = jax.jit(step, donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(psp, osp, bsp)
    elif shape.kind == "prefill":
        def prefill(params, batch):
            return mod.forward(params, batch, cfg, ctx, return_cache=True)
        fn = jax.jit(prefill)
        with mesh:
            lowered = fn.lower(psp, bsp)
    else:  # decode
        csp = specs_lib.cache_specs(cfg, mod, shape, mesh, rules, tp)
        def decode(params, cache, batch):
            return mod.decode_step(params, cache, batch["tokens"], cfg, ctx)
        fn = jax.jit(decode, donate_argnums=(1,))
        with mesh:
            lowered = fn.lower(psp, csp, bsp)
    return cfg, mesh, lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool, force: bool = False):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = REPORT_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        cached = json.loads(out_path.read_text())
        if cached.get("status") != "error":   # always retry failures
            print(f"[skip] {out_path.name} (cached)")
            return cached
    cfg, _ = registry.get(arch)
    if not runnable(cfg, shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": "full-attention arch at 524k context is quadratic; "
                         "cell runs only for SSM/hybrid (DESIGN.md "
                         "§Arch-applicability)"}
        REPORT_DIR.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[skip-by-design] {arch} x {shape_name}")
        return rec

    t0 = time.time()
    try:
        cfg, mesh, lowered = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        n_dev = mesh.devices.size
        hlo_costs = parse_hlo_costs(compiled.as_text())
        coll = hlo_costs["collectives"]
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok",
            "n_devices": int(n_dev),
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            },
            "cost": {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float))},
            "hlo_dot_flops": hlo_costs["flops"],
            "hlo_dot_bytes": hlo_costs["dot_bytes"],
            "collectives": coll,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
        }
    except Exception as e:  # record failures; the suite keeps going
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: {e}")
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    if rec["status"] == "ok":
        print(f"[ok] {arch} x {shape_name} x {mesh_name} "
              f"(lower {rec['lower_s']}s compile {rec['compile_s']}s, "
              f"temp/dev {rec['memory']['temp_bytes']/2**30:.2f} GiB)")
        print("  memory_analysis:", rec["memory"])
        print("  cost_analysis flops:", rec["cost"].get("flops"))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        archs = registry.names()
        shapes = list(SHAPES)
    else:
        archs = [args.arch] if args.arch else registry.names()
        shapes = [args.shape] if args.shape else list(SHAPES)
    failures = 0
    for a in archs:
        for s in shapes:
            rec = run_cell(a, s, args.multi_pod, args.force)
            failures += rec.get("status") == "error"
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
