"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (tests / examples)."""
    n = jax.device_count()
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    return jax.make_mesh((1, 1), ("data", "model"))
