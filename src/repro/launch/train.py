"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container use ``--reduced`` (the full configs are exercised via
the dry-run); on a real fleet the same driver runs the full config with the
production mesh (--mesh single|multi).
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.data.pipeline import TokenBatcher
from repro.data.synthetic import token_corpus
from repro.models import registry
from repro.train import optimizer as opt_lib
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=registry.names())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dedup", action="store_true",
                    help="near-duplicate filtering via the retrieval stack")
    args = ap.parse_args()

    cfg, mod = registry.get(args.arch, reduced=args.reduced)
    corpus = token_corpus(512, args.seq * 4, cfg.vocab, seed=0,
                          dup_frac=0.1 if args.dedup else 0.0)
    if args.dedup:
        from repro.data.pipeline import dedup_corpus
        before = len(corpus)
        corpus = dedup_corpus(corpus, max_docs=min(len(corpus), 128))
        print(f"dedup: {before} -> {len(corpus)} docs")
    batcher = TokenBatcher(corpus, args.batch, args.seq, seed=1)
    ocfg = opt_lib.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                             total_steps=args.steps)
    trainer = Trainer(mod, cfg, ocfg, batcher, args.ckpt_dir,
                      TrainerConfig(total_steps=args.steps,
                                    ckpt_every=args.ckpt_every))
    out = trainer.run()
    print(json.dumps(out["log"][-5:], indent=2))
    first = out["log"][0]["loss"] if out["log"] else float("nan")
    last = out["log"][-1]["loss"] if out["log"] else float("nan")
    print(f"loss {first:.3f} -> {last:.3f} over {out['final_step']} steps")


if __name__ == "__main__":
    main()
