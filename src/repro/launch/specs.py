"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` builds weak-type-correct, shardable abstract values for
every model input — tokens/labels for training, token+cache for decode,
precomputed frame/patch embeddings for the audio/vlm frontend stubs — with
no device allocation (the full configs are only ever exercised this way).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.launch import sharding as shd
from repro.models import registry
from repro.models.params import abstract_params


def _sds(shape, dtype, mesh, rules, *axes):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=shd.sharding(mesh, rules, *axes, shape=shape))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules
                ) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    prefix = cfg.frontend_prefix if cfg.frontend != "none" else 0
    if shape.kind == "train":
        out = {
            "tokens": _sds((B, S - prefix), jnp.int32, mesh, rules,
                           "batch", None),
            "labels": _sds((B, S), jnp.int32, mesh, rules, "batch", None),
        }
        if prefix:
            out["embeds"] = _sds((B, prefix, cfg.d_model), jnp.bfloat16,
                                 mesh, rules, "batch", None, None)
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((B, S - prefix), jnp.int32, mesh, rules,
                              "batch", None)}
        if prefix:
            out["embeds"] = _sds((B, prefix, cfg.d_model), jnp.bfloat16,
                                 mesh, rules, "batch", None, None)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": _sds((B, 1), jnp.int32, mesh, rules, "batch", None)}


def cache_specs(cfg: ModelConfig, mod, shape: ShapeConfig, mesh: Mesh,
                rules, tp: int):
    defs = mod.cache_defs(cfg, shape.global_batch, shape.seq_len, tp)
    def to_sds(d):
        if d is None:
            return None
        return jax.ShapeDtypeStruct(
            d.shape, jnp.bfloat16 if len(d.shape) else jnp.int32,
            sharding=shd.sharding(mesh, rules, *d.axes, shape=d.shape))
    return jax.tree.map(to_sds, defs,
                        is_leaf=lambda x: x is None or hasattr(x, "axes"))


def param_specs(cfg: ModelConfig, mod, mesh: Mesh, rules, tp: int,
                dtype=jnp.bfloat16):
    defs = mod.param_defs(cfg, tp)
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, dtype,
            sharding=shd.sharding(mesh, rules, *d.axes, shape=d.shape)),
        defs, is_leaf=lambda x: hasattr(x, "axes"))


def opt_specs(cfg: ModelConfig, mod, mesh: Mesh, rules, tp: int,
              state_dtype=jnp.float32):
    p = param_specs(cfg, mod, mesh, rules, tp, dtype=state_dtype)
    return {
        "m": p, "v": p,
        "step": jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=shd.sharding(mesh, rules)),
    }
