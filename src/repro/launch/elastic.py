"""Elastic scaling for the sharded retrieval fleet — on the batched substrate.

Windows are assigned to shards by rendezvous (highest-random-weight)
hashing: when the worker set changes, ONLY the windows whose owner changed
move — each survivor keeps ~n/k of its data, so an N->N±1 resize touches
~1/N of the index instead of all of it.  Each shard owns an independent
reference net (metric-space partitioning keeps range queries exact by
union; DESIGN.md §4.3).

Since PR 3 the elastic layer is the fleet-serving front end of the batched
substrate rather than a host-only wrapper:

* **Construction** — every shard builds through
  :meth:`~repro.core.refnet.ReferenceNet.build_batched` on a
  caller-selected :class:`~repro.core.counter.CountedDistance` backend
  (``numpy`` / ``jax`` / ``pallas``), and is immediately flattened
  (:func:`~repro.core.distributed.flatten_net`) so it can serve device
  queries.
* **Resharding** — :meth:`ElasticIndex.resize` never rebuilds a surviving
  shard from scratch.  Windows that rendezvous moves *out* are deleted from
  the host net (Alg. 2 re-homing) and masked out of the shard's
  :class:`~repro.core.distributed.FlatNet` with zero evaluations
  (:meth:`FlatNet.remove`); windows that move *in* are appended to the
  shard's database (:meth:`ReferenceNet.extend_data`), bulk-loaded through
  the cohort loader (``build_batched(order=new_ids)``), and attached to the
  flat net incrementally (:meth:`FlatNet.append`) under a pivot ancestor
  found by walking the new node's parent chain.  Only a brand-new worker
  (or the rare shard whose *root* window moved away) pays a full build, so
  an N->N+1 resize re-spends ~1/N of the original ``build``-bucket cost
  (gated in ``benchmarks/bench_elastic.py``).
* **Serving** — :meth:`ElasticIndex.range_query_batch` answers the fleet
  in one of two batched modes:

  - ``mode="rounds"`` (the default): the **shared-frontier, round-based
    path**.  Every alive shard contributes one Alg.-3 range-query plan per
    query, and a :class:`~repro.core.batch_engine.FleetBatchEngine` drives
    them all in lockstep — each merged round is ONE evaluator call across
    all shards and all length buckets (the packed ragged-bucket kernel
    dispatch with fused ε-pruning on the ``pallas`` backend), with hit
    lists flowing back through each shard's ``gids`` to global ids.  The
    frontier's round-by-round pruning is preserved exactly: evaluation
    counts match the host per-shard loop row for row, tallied in
    :attr:`ElasticIndex.device_stats` (never the host counters).
  - ``mode="oneshot"``: the legacy stacked path — the alive shards'
    FlatNets merge via ``merge_flats`` into ONE
    :func:`~repro.core.distributed.fleet_range_query` device call.  One
    dispatch total, but only the flat net's pivot/ring bounds prune, so it
    evaluates far more candidates than the frontier does (kept for
    single-dispatch serving and as the stacked parity path).

  ``dead`` workers are masked out of either path (their plans are never
  admitted / their columns never merged), so a lost worker degrades the
  answer to the union of the survivors (exact on their partitions) until
  the caller ``resize``\\ s it away.  ``batched=False`` on
  :meth:`ElasticIndex.range_query` keeps the classic host per-shard
  pointer-chasing loop — same hit sets, used as the parity oracle.

Accounting: :meth:`ElasticIndex.eval_count` reports the fleet's host-side
counter totals as separate ``{"query", "build"}`` buckets (construction
and resharding land in ``build``, host-mode queries in ``query``; counts
of retired shards are retained so both buckets are monotone across
resizes), and :attr:`ElasticIndex.device_stats` accumulates the device
path's pivot/member evaluation totals.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np


def _hrw_score(window_id: int, worker: str) -> int:
    h = hashlib.blake2b(f"{window_id}:{worker}".encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big")


def assign(window_ids: Sequence[int], workers: Sequence[str]
           ) -> Dict[str, List[int]]:
    """Rendezvous-hash every window to a worker."""
    out: Dict[str, List[int]] = {w: [] for w in workers}
    for wid in window_ids:
        best = max(workers, key=lambda w: _hrw_score(wid, w))
        out[best].append(wid)
    return out


def moved_fraction(before: Dict[str, List[int]], after: Dict[str, List[int]]
                   ) -> float:
    owner_b = {wid: w for w, wids in before.items() for wid in wids}
    owner_a = {wid: w for w, wids in after.items() for wid in wids}
    moved = sum(1 for wid, w in owner_a.items()
                if owner_b.get(wid) != w)
    return moved / max(len(owner_a), 1)


@dataclasses.dataclass
class _Shard:
    """One worker's slice of the fleet: host net + device flat + id map.

    ``gids[i]`` is the global window id stored at local row ``i`` of the
    shard's database.  Rows are not recycled in place: a window that
    reshards away leaves a stale row behind (masked out of both the net
    and the flat), a window that reshards in appends a fresh row — so
    local ids stay stable across incremental resizes, and ``resize``
    compacts a shard (full rebuild) once stale rows outnumber live ones."""
    net: "object"               # ReferenceNet over the shard-local database
    flat: "object"              # FlatNet serving the device path
    gids: np.ndarray            # (rows,) local row -> global window id


#: batched serving modes: shared-frontier rounds vs the legacy one-shot
#: stacked device query (see the module docstring)
FLEET_MODES = ("rounds", "oneshot")


class ElasticIndex:
    """A set of per-shard reference nets that reshard incrementally and
    serve batched fleet queries round-based (shared frontier) or as one
    stacked device query.

    Deprecated as a *direct* public entry point since v0.1 — build through
    the facade instead::

        repro.retrieval.Retriever.build(
            RetrievalConfig(dist, execution="fleet", workers=...), data)

    The facade delegates here, so behavior and counts are identical; this
    constructor shim will be removed in v0.2.
    ``dist`` accepts a registry name or a ``Distance`` instance."""

    def __init__(self, dist, data: np.ndarray, workers: List[str],
                 *, eps_prime: float = 1.0, tight_bounds: bool = True,
                 backend: str = "numpy", max_cohort: int = 256,
                 interpret: bool = True, fleet_mode: str = "rounds",
                 lb_cascade="off", kernel_exec=None, kernel_tile=None):
        from repro.core import _deprecation
        from repro.distances import base as dist_base
        from repro.distances import bounds as dist_bounds
        _deprecation.warn_legacy("ElasticIndex")
        if fleet_mode not in FLEET_MODES:
            raise ValueError(
                f"fleet_mode must be one of {FLEET_MODES}; "
                f"got {fleet_mode!r}")
        self.lb_cascade = dist_bounds.normalize_tier(lb_cascade)
        if self.lb_cascade == "endpoint":
            raise ValueError(
                "the fleet path supports lb_cascade='envelope' only (the "
                "endpoint tier belongs to the host/batched frontier engine)")
        self.dist = dist_base.require_metric(dist)
        self.data = np.asarray(data)
        self.eps_prime = eps_prime
        self.tight = tight_bounds
        self.backend = backend
        self.kernel_exec = kernel_exec
        self.kernel_tile = kernel_tile
        self.max_cohort = max_cohort
        self.interpret = interpret
        self.fleet_mode = fleet_mode
        self.workers = list(workers)
        self.assignment = assign(range(len(data)), self.workers)
        self._retired = {"query": 0, "build": 0}
        self._merged = None     # (dead_ix, merge_flats result) serving cache
        self._round_eval = None  # resolved (evaluate, fused) for mode=rounds
        self.device_stats = {"pivot_evals": 0, "member_evals": 0,
                             "fused_pruned": 0, "total_evals": 0,
                             "lb_rows": 0, "lb_pruned": 0,
                             "rounds": 0, "device_queries": 0}
        self.shards: Dict[str, Optional[_Shard]] = {
            w: self._build_shard(self.assignment[w]) for w in self.workers}

    # -- construction -------------------------------------------------------

    def _build_shard(self, ids: Sequence[int]) -> Optional[_Shard]:
        """Full cohort build of one shard on the selected backend."""
        from repro.core.counter import CountedDistance
        from repro.core.distributed import flatten_net
        from repro.core.refnet import ReferenceNet
        if not len(ids):
            return None
        ids = np.asarray(ids, np.int64)
        counter = CountedDistance(self.dist, self.data[ids],
                                  backend=self.backend,
                                  kernel_exec=self.kernel_exec,
                                  kernel_tile=self.kernel_tile)
        net = ReferenceNet(self.dist, counter.data,
                           eps_prime=self.eps_prime,
                           tight_bounds=self.tight, counter=counter)
        net.build_batched(max_cohort=self.max_cohort)
        return _Shard(net=net, flat=flatten_net(net), gids=ids)

    def _retire(self, shard: _Shard) -> None:
        """Fold a dropped/replaced shard's counters into the running totals
        so ``eval_count`` buckets stay monotone across resizes."""
        self._retired["query"] += shard.net.counter.count
        self._retired["build"] += shard.net.counter.build_count

    # -- elastic resharding -------------------------------------------------

    def resize(self, workers: List[str]) -> float:
        """Change the worker set; reshard incrementally.

        Surviving shards shrink (Alg.-2 deletes + zero-eval ``FlatNet``
        masking) and/or grow (``extend_data`` + cohort bulk load +
        ``FlatNet.append``); a full ``build_batched`` is paid only by
        brand-new workers, the rare shard whose root window moved away,
        and shards whose accumulated stale rows outnumber their live ones
        (churn compaction).  Returns the fraction of windows that moved."""
        new_assign = assign(range(len(self.data)), workers)
        frac = moved_fraction(self.assignment, new_assign)
        old_shards = self.shards
        new_shards: Dict[str, Optional[_Shard]] = {}
        for w in workers:
            old = old_shards.get(w)
            new_ids = new_assign[w]
            if old is not None and new_ids == self.assignment.get(w):
                new_shards[w] = old                     # untouched shard
                continue
            shard: Optional[_Shard] = None
            if old is not None and new_ids:
                old_set = set(self.assignment.get(w, ()))
                new_set = set(new_ids)
                lost = sorted(old_set - new_set)
                gained = sorted(new_set - old_set)
                # churn compaction, decided BEFORE spending any incremental
                # work: if stale rows would outnumber live windows, a full
                # rebuild is the cheaper (and smaller) shard
                rows_after = len(old.gids) + len(gained)
                live_after = len(old.net.nodes) - len(lost) + len(gained)
                if live_after * 2 >= rows_after:
                    shard = self._shrink(old, lost) if lost else old
                    if shard is not None and gained:
                        self._grow(shard, gained)
            if shard is None and new_ids:
                shard = self._build_shard(new_ids)  # new/root-loss/compaction
            new_shards[w] = shard
        carried = {id(s) for s in new_shards.values() if s is not None}
        for s in old_shards.values():
            if s is not None and id(s) not in carried:
                self._retire(s)
        self.assignment = new_assign
        self.workers = list(workers)
        self.shards = new_shards
        self._merged = None     # shard arrays changed: drop the serving cache
        return frac

    def _shrink(self, shard: _Shard, lost: Sequence[int]
                ) -> Optional[_Shard]:
        """Remove windows that resharded away.  Host net: Alg.-2 deletion
        (plain objects first, then references bottom-up, so a deleted
        reference never re-homes a child that is itself leaving).  Flat
        net: zero-eval member masking.  Returns None — full rebuild — only
        when the shard's root window itself moved away."""
        g2l = {int(g): i for i, g in enumerate(shard.gids)}
        local = [g2l[int(g)] for g in lost]
        net = shard.net
        if net.root in local:
            return None
        objs = [x for x in local if net.nodes[x].level < 0]
        refs = sorted((x for x in local if net.nodes[x].level >= 0),
                      key=lambda x: net.nodes[x].level)
        for x in objs + refs:
            net.delete(x)
        shard.flat.remove(local)
        return shard

    def _grow(self, shard: _Shard, gained: Sequence[int]) -> None:
        """Bulk-load windows that resharded in: extend the shard database,
        run the cohort loader over just the new ids, and attach each new
        window to the flat net under a pivot ancestor (walking the parent
        chain; link distances are reused where the pivot is the direct
        parent, the rest are one stacked build-bucket dispatch)."""
        gained = np.asarray(sorted(int(g) for g in gained), np.int64)
        rows = self.data[gained]
        net = shard.net
        new_local = net.extend_data(rows)
        shard.gids = np.concatenate([shard.gids, gained])
        net.build_batched(order=new_local, max_cohort=self.max_cohort)
        self._refresh_flat(shard, new_local, rows)

    def _refresh_flat(self, shard: _Shard, new_local: Sequence[int],
                      rows: np.ndarray) -> None:
        flat, net = shard.flat, shard.net
        pivot_row = {int(p): r
                     for r, p in enumerate(np.asarray(flat.pivot_ids))}
        prows: List[int] = []
        dists: List[float] = []
        need_l: List[int] = []
        need_r: List[int] = []
        need_at: List[int] = []
        for x in new_local:
            p = x
            while p not in pivot_row:
                p = net.nodes[p].parents[0]   # levels strictly increase
            prows.append(pivot_row[p])
            pn = net.nodes[p]
            if x in pn.children:
                dists.append(float(pn.child_dist[pn.children.index(x)]))
            else:
                need_l.append(p)
                need_r.append(x)
                need_at.append(len(dists))
                dists.append(0.0)
        if need_l:
            ds = net.counter.eval_pairs(need_l, need_r)
            for at, d in zip(need_at, ds):
                dists[at] = float(d)
        flat.append(prows, list(new_local), dists, new_data=rows)

    # -- serving ------------------------------------------------------------

    def range_query(self, q: np.ndarray, eps: float,
                    q_len: Optional[int] = None, dead: Sequence[str] = (),
                    *, batched: bool = True,
                    capacity: Optional[int] = None,
                    mode: Optional[str] = None) -> List[int]:
        """Fleet-wide query = union over shards (exact).  ``dead`` workers
        are skipped — results degrade gracefully and the caller can retry
        after `resize` (fault tolerance path).

        ``batched=True`` (default) serves through the batched fleet path
        (``mode``: see :meth:`range_query_batch`); ``batched=False`` is the
        host per-shard loop (same hits)."""
        q = np.asarray(q)
        qlen = len(q) if q_len is None else int(q_len)
        if not batched:
            out: List[int] = []
            for w in self.workers:
                s = self.shards.get(w)
                if w in dead or s is None:
                    continue
                # lint: allow[dispatch-in-loop] -- host per-shard parity loop: the sequential reference the stacked fleet path is asserted against
                for local in s.net.range_query(q, eps, qlen):
                    out.append(int(s.gids[local]))
            return sorted(out)
        return self.range_query_batch([q[:qlen]], eps, dead=dead,
                                      capacity=capacity, mode=mode)[0]

    def range_query_batch(self, qs: Union[np.ndarray, Sequence[np.ndarray]],
                          eps: float, *, dead: Sequence[str] = (),
                          capacity: Optional[int] = None,
                          mode: Optional[str] = None) -> List[List[int]]:
        """Batched fleet serving for a whole query batch.

        ``mode`` (default: the constructor's ``fleet_mode``, ``"rounds"``):

        * ``"rounds"`` — shared-frontier round-based serving: every alive
          shard runs one Alg.-3 range-query plan per query, all plans
          advance in lockstep, and each merged round is ONE evaluator call
          across all shards and all length buckets (the packed fused-ε
          kernel dispatch on the ``pallas`` backend).  Pruning — and the
          evaluation count — is identical to the host per-shard loop.
        * ``"oneshot"`` — the legacy stacked path: ``merge_flats`` + ONE
          ``fleet_range_query`` device call for the whole batch
          (``capacity`` applies here).

        ``qs`` is a (Q, l[, d]) array or a sequence of query windows whose
        lengths may differ — mixed lengths ride the packed ragged-bucket
        dispatch with per-query lengths.  Returns the sorted global hit
        ids per query; ``dead`` workers are masked out of either path."""
        mode = self.fleet_mode if mode is None else mode
        if mode not in FLEET_MODES:
            raise ValueError(
                f"mode must be one of {FLEET_MODES}; got {mode!r}")
        rows = [np.asarray(q) for q in qs]
        if not rows:
            return []
        dead_ix = tuple(i for i, w in enumerate(self.workers)
                        if w in dead or self.shards.get(w) is None)
        if mode == "rounds":
            return self._round_query(rows, eps, dead_ix)
        return self._oneshot_query(rows, eps, dead_ix, capacity)

    # -- round-based serving (shared frontier, fused-ε pruning) -------------

    def _round_evaluator(self):
        """Resolve the round evaluator once: ``(evaluate, fused)``.

        On the ``pallas`` backend (with a registered kernel) a merged round
        goes straight through the packed ragged-bucket dispatcher with
        per-row shard provenance and fused ε-pruning — the kernel returns
        the hit verdict and never materializes pruned candidates'
        distances.  Other backends evaluate the round in one host batch
        call (values still preserve every ``<= eps`` verdict)."""
        if self._round_eval is not None:
            return self._round_eval
        from repro.kernels import registry as kernel_registry
        if self.backend == "pallas" and kernel_registry.has(self.dist.name):
            from repro.kernels.dispatch import packed_batch

            def evaluate(xs, ys, lx, ly, eps_rows, shard_ids):
                out = packed_batch(self.dist.name, xs, ys, lx, ly,
                                   eps=eps_rows, interpret=self.interpret,
                                   exec=self.kernel_exec,
                                   tile=self.kernel_tile,
                                   shards=shard_ids)
                return (np.asarray(out.dist, np.float32),
                        int(np.asarray(out.pruned).sum()))

            self._round_eval = (evaluate, True)
        else:
            from repro.core.counter import _resolve_backend
            batch = _resolve_backend(self.dist, self.backend,
                                     self.kernel_exec, self.kernel_tile)

            def evaluate(xs, ys, lx, ly, eps_rows, shard_ids):
                return np.asarray(batch(xs, ys, lx, ly), np.float32), 0

            self._round_eval = (evaluate, False)
        return self._round_eval

    def _round_query(self, rows: List[np.ndarray], eps: float,
                     dead_ix: Tuple[int, ...]) -> List[List[int]]:
        """Shared-frontier rounds across all alive shards (one evaluator
        call per merged round); evaluation totals land in
        :attr:`device_stats`, never the shards' host counters."""
        from repro.core.batch_engine import FleetBatchEngine, ShardPlans
        from repro.kernels.dispatch import pad_ragged_rows
        qpad, q_lens = pad_ragged_rows(rows)
        groups = []
        for si, w in enumerate(self.workers):
            s = self.shards.get(w)
            if si in dead_ix or s is None:
                continue
            groups.append(ShardPlans(
                shard=si, data=s.net.data,
                plans=[s.net.range_query_plan(eps) for _ in rows],
                queries=qpad, q_lens=q_lens))
        lb_hook = None
        if self.lb_cascade == "envelope" and groups:
            # envelope tier over each shard's PRECOMPUTED FlatNet envelopes
            # (built once at flatten time, refreshed by append) — the hook
            # gathers stored boxes/masses per candidate id, no per-round
            # recomputation of O(rows * L) reductions
            from repro.distances import bounds as dist_bounds
            envs = {}
            for si, w in enumerate(self.workers):
                s = self.shards.get(w)
                if s is not None and s.flat.envelopes is not None:
                    envs[si] = s.flat.envelopes
            if envs:
                name = self.dist.name

                def lb_hook(shard, idxs, q, q_len):
                    e = envs[shard].take(idxs)
                    xs = np.repeat(q[None], len(idxs), 0)
                    return dist_bounds.lb_envelope_rows(
                        name, xs, np.full(len(idxs), q_len, np.int64),
                        e.lo, e.hi, e.mass)

        evaluate, fused = self._round_evaluator()
        engine = FleetBatchEngine(evaluate, fused=fused, lb=lb_hook)
        per_group = engine.run(groups, eps)
        hits: List[set] = [set() for _ in rows]
        for grp, res in zip(groups, per_group):
            gids = self.shards[self.workers[grp.shard]].gids
            for qi, local in enumerate(res):
                hits[qi].update(int(gids[x]) for x in local)
        agg = self.device_stats
        agg["pivot_evals"] += engine.exact_evals
        agg["member_evals"] += engine.verdict_evals
        agg["fused_pruned"] += engine.fused_pruned
        agg["lb_rows"] += engine.lb_rows
        agg["lb_pruned"] += engine.lb_pruned
        agg["total_evals"] += engine.exact_evals + engine.verdict_evals
        agg["rounds"] += engine.rounds
        agg["device_queries"] += 1
        return [sorted(h) for h in hits]

    # -- one-shot stacked serving (legacy fallback) -------------------------

    def _oneshot_query(self, rows: List[np.ndarray], eps: float,
                       dead_ix: Tuple[int, ...],
                       capacity: Optional[int]) -> List[List[int]]:
        """ONE stacked device query through ``merge_flats`` +
        ``fleet_range_query`` — a single dispatch, but only flat-net
        pivot/ring bounds prune (no frontier rounds)."""
        from repro.core.distributed import fleet_range_query, merge_flats
        flats = [self.shards[w].flat if self.shards.get(w) is not None
                 else None for w in self.workers]
        # the merged fleet arrays only change on resize, so reuse them
        # across queries instead of re-stacking the whole fleet per call
        if self._merged is not None and self._merged[0] == dead_ix:
            merged = self._merged[1]
        else:
            alive = [f for i, f in enumerate(flats) if i not in dead_ix]
            merged = merge_flats(alive) if len(alive) > 1 else None
            self._merged = (dead_ix, merged)
        hits: List[set] = [set() for _ in rows]
        from repro.kernels.dispatch import pad_ragged_rows
        qb, q_lens = pad_ragged_rows(rows)
        res, stats = fleet_range_query(
            flats, qb, eps, dead=dead_ix, stacked=True, merged=merged,
            capacity=capacity, interpret=self.interpret,
            lb_cascade=self.lb_cascade,
            q_lens=None if (q_lens == qb.shape[1]).all()
            else q_lens.astype(np.int32))
        self._note_stats(stats)
        for i, w in enumerate(self.workers):
            if res[i] is None:
                continue
            gids = self.shards[w].gids
            for qi in range(len(rows)):
                hits[qi].update(gids[np.flatnonzero(res[i][qi])].tolist())
        return [sorted(h) for h in hits]

    def _note_stats(self, stats: Sequence[Optional[dict]]) -> None:
        """Accumulate device-path evaluation totals (merged fleet stats are
        shared dicts — counted once, not once per shard)."""
        agg = self.device_stats
        seen_merged = False
        for st in stats:
            if st is None:
                continue
            if st.get("merged"):
                if seen_merged:
                    continue
                seen_merged = True
                agg["pivot_evals"] += st["fleet_pivot_evals"]
                agg["member_evals"] += st["fleet_member_evals"]
                agg["fused_pruned"] += st.get("fleet_fused_pruned", 0)
                agg["lb_rows"] += st.get("fleet_lb_rows", 0)
                agg["lb_pruned"] += st.get("fleet_lb_pruned", 0)
                agg["total_evals"] += st["fleet_total_evals"]
            else:
                agg["pivot_evals"] += st["pivot_evals"]
                agg["member_evals"] += st["member_evals"]
                agg["fused_pruned"] += st.get("fused_pruned", 0)
                agg["lb_rows"] += st.get("lb_rows", 0)
                agg["lb_pruned"] += st.get("lb_pruned", 0)
                agg["total_evals"] += st["total_evals"]
        agg["device_queries"] += 1

    # -- accounting ---------------------------------------------------------

    def eval_count(self) -> Dict[str, int]:
        """Host-side counter totals by bucket: ``query`` (host-mode range
        queries) and ``build`` (construction + resharding).  Retired shards'
        counts are retained, so both buckets are monotone across resizes;
        device-path evaluations are tracked in :attr:`device_stats`."""
        out = dict(self._retired)
        for s in self.shards.values():
            if s is None:
                continue
            out["query"] += s.net.counter.count
            out["build"] += s.net.counter.build_count
        return out
