"""Elastic scaling for the sharded retrieval fleet.

Windows are assigned to shards by rendezvous (highest-random-weight)
hashing: when the worker set changes, ONLY the windows whose owner changed
move — each survivor keeps ~n/k of its data, so an N->N±1 resize rebuilds
~1/N of the index instead of all of it.  Each shard owns an independent
reference net (metric-space partitioning keeps range queries exact by
union; DESIGN.md §4.3).
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Sequence

import numpy as np


def _hrw_score(window_id: int, worker: str) -> int:
    h = hashlib.blake2b(f"{window_id}:{worker}".encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "big")


def assign(window_ids: Sequence[int], workers: Sequence[str]
           ) -> Dict[str, List[int]]:
    """Rendezvous-hash every window to a worker."""
    out: Dict[str, List[int]] = {w: [] for w in workers}
    for wid in window_ids:
        best = max(workers, key=lambda w: _hrw_score(wid, w))
        out[best].append(wid)
    return out


def moved_fraction(before: Dict[str, List[int]], after: Dict[str, List[int]]
                   ) -> float:
    owner_b = {wid: w for w, wids in before.items() for wid in wids}
    owner_a = {wid: w for w, wids in after.items() for wid in wids}
    moved = sum(1 for wid, w in owner_a.items()
                if owner_b.get(wid) != w)
    return moved / max(len(owner_a), 1)


class ElasticIndex:
    """A set of per-shard reference nets that reshard incrementally."""

    def __init__(self, dist_name: str, data: np.ndarray, workers: List[str],
                 *, eps_prime: float = 1.0, tight_bounds: bool = True):
        from repro.core.refnet import ReferenceNet
        from repro.distances import get
        self.dist = get(dist_name)
        self.data = np.asarray(data)
        self.eps_prime = eps_prime
        self.tight = tight_bounds
        self.workers = list(workers)
        self.assignment = assign(range(len(data)), self.workers)
        self._net_cls = ReferenceNet
        self.shards = {w: self._build(w) for w in self.workers}

    def _build(self, worker: str):
        ids = self.assignment[worker]
        if not ids:
            return None
        net = self._net_cls(self.dist, self.data[ids],
                            eps_prime=self.eps_prime,
                            tight_bounds=self.tight).build()
        net._global_ids = list(ids)
        return net

    def resize(self, workers: List[str]) -> float:
        """Change the worker set; rebuild only shards whose content moved.
        Returns the fraction of windows that moved."""
        new_assign = assign(range(len(self.data)), workers)
        frac = moved_fraction(self.assignment, new_assign)
        new_shards = {}
        for w in workers:
            if w in self.shards and new_assign[w] == self.assignment.get(w):
                new_shards[w] = self.shards[w]  # untouched shard
            else:
                new_shards[w] = None            # content changed: rebuild
        self.assignment = new_assign
        self.workers = list(workers)
        for w in workers:
            if new_shards[w] is None:
                new_shards[w] = self._build(w)
        self.shards = new_shards
        return frac

    def range_query(self, q: np.ndarray, eps: float,
                    q_len=None, dead: Sequence[str] = ()) -> List[int]:
        """Fleet-wide query = union over shards (exact).  ``dead`` workers
        are skipped — results degrade gracefully and the caller can retry
        after `resize` (fault tolerance path)."""
        out: List[int] = []
        for w in self.workers:
            if w in dead or self.shards[w] is None:
                continue
            net = self.shards[w]
            for local in net.range_query(q, eps, q_len):
                out.append(net._global_ids[local])
        return sorted(out)

    def eval_count(self) -> int:
        return sum(s.counter.count for s in self.shards.values()
                   if s is not None)
