"""Retrieval serving driver — the paper's kind of serving: a sharded
subsequence-retrieval fleet answering batched queries.

  PYTHONPATH=src python -m repro.launch.serve --dataset proteins \
      --n-windows 2000 --shards 4 --queries 32 --eps 2.0

Builds per-shard reference nets (elastic, rendezvous-hashed), answers a
batch of range + type-II/III queries, reports pruning ratios and latency,
and exercises the straggler-work-stealing path with a simulated slow shard.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.matching import SubsequenceMatcher
from repro.data import synthetic
from repro.launch.elastic import ElasticIndex


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="proteins",
                    choices=["proteins", "songs", "traj"])
    ap.add_argument("--distance", default=None)
    ap.add_argument("--n-windows", type=int, default=2000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--eps", type=float, default=2.0)
    args = ap.parse_args()

    gen, default_dist = synthetic.DATASETS[args.dataset]
    dist = args.distance or default_dist or "erp"
    data = gen(args.n_windows, seed=0)
    rng = np.random.default_rng(1)

    workers = [f"worker{i}" for i in range(args.shards)]
    t0 = time.time()
    fleet = ElasticIndex(dist, data, workers, tight_bounds=True)
    build_s = time.time() - t0

    queries = data[rng.integers(0, len(data), args.queries)].copy()
    if data.dtype.kind == "i":
        flips = rng.random(queries.shape) < 0.1
        queries[flips] = rng.integers(0, queries.max() + 1, flips.sum())
    else:
        queries += rng.normal(scale=0.1, size=queries.shape).astype(
            queries.dtype)

    # stacked device serving: the whole query batch is ONE fleet query
    # (merge_flats + one device dispatch per length bucket)
    t0 = time.time()
    batch_hits = fleet.range_query_batch(queries, args.eps)
    serve_s = time.time() - t0
    n_hits = sum(len(h) for h in batch_hits)

    # host per-shard loop: same hits, classic per-eval counting (the
    # paper's pruning-ratio currency lives in the counter's query bucket)
    t0 = time.time()
    loop_hits = [fleet.range_query(q, args.eps, batched=False)
                 for q in queries]
    loop_s = time.time() - t0
    assert batch_hits == loop_hits, "stacked serving must stay exact"
    evals = fleet.eval_count()
    naive = args.queries * len(data)

    # straggler mitigation: shard 0 is slow -> it is masked `dead` in the
    # stacked fleet query and its share re-issued against a replica
    replica = ElasticIndex(dist, data, workers, tight_bounds=True)
    t0 = time.time()
    part_hits = fleet.range_query_batch(queries, args.eps,
                                        dead=("worker0",))
    rep = replica.shards["worker0"]
    stolen_hits = 0
    for part, q in zip(part_hits, queries):
        extra = [int(rep.gids[i])
                 for i in rep.net.range_query(q, args.eps)] if rep else []
        stolen_hits += len(set(part) | set(extra))
    steal_s = time.time() - t0
    assert stolen_hits == n_hits, "work stealing must preserve exactness"

    # elastic resize: drop one worker, verify exactness is preserved and
    # the incremental reshard cost lands in the build bucket
    build_before = fleet.eval_count()["build"]
    frac = fleet.resize(workers[:-1])
    resize_evals = fleet.eval_count()["build"] - build_before
    n_hits2 = sum(len(h) for h in fleet.range_query_batch(queries, args.eps))
    assert n_hits2 == n_hits, "resharding must preserve exactness"

    print(json.dumps({
        "dataset": args.dataset, "distance": dist,
        "windows": len(data), "shards": args.shards,
        "build_s": round(build_s, 2),
        "batch_queries": args.queries,
        "serve_s": round(serve_s, 3),
        "qps": round(args.queries / serve_s, 1),
        "loop_s": round(loop_s, 3),
        "loop_qps": round(args.queries / loop_s, 1),
        "hits": n_hits,
        "query_evals": evals["query"],
        "build_evals": evals["build"],
        "device_evals": fleet.device_stats["total_evals"],
        "evals_vs_naive": round(evals["query"] / naive, 4),
        "steal_s": round(steal_s, 3),
        "resize_moved_frac": round(frac, 3),
        "resize_build_evals": resize_evals,
    }, indent=2))


if __name__ == "__main__":
    main()
