"""Continuous-batching serve CLI — the front end of the PR-9 serve engine.

  PYTHONPATH=src python -m repro.launch.serve --dataset proteins \
      --n-windows 2000 --shards 4 --queries 32 --eps 2.0 --qps 16

  # or declaratively: the whole retrieval stack from one JSON config
  PYTHONPATH=src python -m repro.launch.serve --config fleet.json \
      --qps 16 --duration 2.0 --snapshot-dir /tmp/fleet-snaps

``--config path.json`` deserializes straight into
:class:`~repro.retrieval.RetrievalConfig` (the file is exactly
``RetrievalConfig.to_json()`` output).  The driver builds the fleet
through the :class:`~repro.retrieval.Retriever` facade, then serves an
open-loop Poisson request stream through the continuous-batching
:class:`~repro.serve.engine.ServeEngine`: asynchronous requests join the
shared frontier cadence mid-flight (one packed dispatch per merged
round), a mid-load ``resize()`` runs through the zero-downtime
snapshot-swap path, and every answer is cross-checked against the host
per-shard oracle loop.  Latency lands as p50/p95/p99 percentiles.

Timing methodology: an UNTIMED warmup batch runs first, so the timed
section measures warm serving — first-call trace/compile never pollutes
the reported qps (``traces_timed`` in the output counts kernel traces
inside the timed window; warm serving keeps it at zero).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.data import synthetic
from repro.kernels import registry as kernel_registry
from repro.retrieval import RetrievalConfig, Retriever
from repro.serve import OpenLoopLoadGen


def build_config(args) -> RetrievalConfig:
    """``--config path.json`` round-trips the declarative config; otherwise
    the legacy flags assemble the same dataclass."""
    if args.config:
        cfg = RetrievalConfig.from_json(
            pathlib.Path(args.config).read_text())
        if cfg.execution != "fleet":
            raise SystemExit(
                f"serve.py drives a fleet; config has "
                f"execution={cfg.execution!r}")
        return cfg
    _, default_dist = synthetic.DATASETS[args.dataset]
    return RetrievalConfig(
        distance=args.distance or default_dist or "erp",
        execution="fleet",
        workers=[f"worker{i}" for i in range(args.shards)],
        tight_bounds=True)


def make_queries(data: np.ndarray, n: int, rng) -> np.ndarray:
    """Database rows perturbed into near-miss queries."""
    queries = data[rng.integers(0, len(data), n)].copy()
    if data.dtype.kind == "i":
        flips = rng.random(queries.shape) < 0.1
        queries[flips] = rng.integers(0, queries.max() + 1, flips.sum())
    else:
        queries += rng.normal(scale=0.1, size=queries.shape).astype(
            queries.dtype)
    return queries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None,
                    help="path to a RetrievalConfig JSON (to_json output); "
                         "replaces --distance/--shards")
    ap.add_argument("--dataset", default="proteins",
                    choices=["proteins", "songs", "traj"])
    ap.add_argument("--distance", default=None)
    ap.add_argument("--n-windows", type=int, default=2000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries", type=int, default=32,
                    help="distinct query windows (cycled if --duration "
                         "asks for more requests)")
    ap.add_argument("--eps", type=float, default=2.0)
    ap.add_argument("--qps", type=float, default=8.0,
                    help="open-loop Poisson arrival rate")
    ap.add_argument("--duration", type=float, default=None,
                    help="seconds of load (default: queries/qps)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="fleet snapshot directory (default: a temp dir)")
    ap.add_argument("--resize-to", type=int, default=-1,
                    help="mid-load zero-downtime resize to this many "
                         "workers (-1 = one fewer than built; 0 = skip)")
    args = ap.parse_args()

    config = build_config(args)
    if args.snapshot_dir:
        config = config.replace(serve_snapshot_dir=args.snapshot_dir)
    gen, _ = synthetic.DATASETS[args.dataset]
    data = gen(args.n_windows, seed=0)
    rng = np.random.default_rng(1)

    t0 = time.time()
    fleet = Retriever.build(config, data)
    build_s = time.time() - t0
    workers = fleet.elastic().workers

    queries = make_queries(data, args.queries, rng)
    n_requests = len(queries) if args.duration is None \
        else max(1, int(args.qps * args.duration))
    qlist = [queries[i % len(queries)] for i in range(n_requests)]

    # oracle BEFORE serving: the host per-shard loop in ONE facade batch
    # call (hit sets are shard-layout-invariant, so it stays valid across
    # the mid-load resize below)
    oracle = fleet.batch(queries).via("host").range(args.eps).hits

    # UNTIMED warmup: compile/trace every kernel shape the serve path hits,
    # so the timed section below measures warm serving only
    fleet.batch(queries[:2]).range(args.eps)
    traces0 = kernel_registry.STATS["traces"]

    engine = fleet.serve(args.eps).start()
    load = OpenLoopLoadGen(engine, qlist, args.qps, eps=args.eps).start()
    t0 = time.time()
    resize_to = (len(workers) - 1 if args.resize_to == -1
                 else args.resize_to)
    did_resize = False
    if resize_to and resize_to != len(workers):
        # mid-load: snapshot -> reshard a clone off-path -> swap at a
        # round boundary; the stream keeps serving throughout
        time.sleep(0.5 / args.qps)
        new_workers = (workers[:resize_to] if resize_to < len(workers)
                       else workers + [f"w{i}" for i in
                                       range(resize_to - len(workers))])
        engine.resize(new_workers, block=False)
        did_resize = True
    reqs = load.join()
    if did_resize:
        deadline = time.time() + 60
        while engine.swaps == 0 and time.time() < deadline:
            time.sleep(1e-3)
    engine.close(drain=True)
    serve_s = time.time() - t0
    traces_timed = kernel_registry.STATS["traces"] - traces0

    mismatched = [i for i, r in enumerate(reqs)
                  if not r.done or r.hits != oracle[i % len(queries)]]
    assert not mismatched, f"serving drifted from oracle: {mismatched}"
    if did_resize:
        assert engine.swaps == 1, "snapshot-swap resize did not complete"
        post = [engine.submit(q) for q in queries]
        engine.start()
        engine.close(drain=True)
        assert [r.result() for r in post] == oracle, \
            "post-swap serving must stay exact"

    lat = engine.latency_stats()
    stats = engine.engine_stats()
    evals = fleet.eval_stats()
    print(json.dumps({
        "dataset": args.dataset, "distance": config.dist.name,
        "config": config.to_dict(),
        "windows": len(data), "shards": len(workers),
        "build_s": round(build_s, 2),
        "requests": len(reqs),
        "serve_s": round(serve_s, 3),
        "warm_qps": round(len(reqs) / serve_s, 1),
        "traces_timed": traces_timed,
        "merged_rounds": stats["rounds"],
        "mean_rounds_per_request": lat.get("mean_rounds"),
        "swaps": stats["swaps"],
        "latency_p50_ms": round(1e3 * lat["p50"], 2),
        "latency_p95_ms": round(1e3 * lat["p95"], 2),
        "latency_p99_ms": round(1e3 * lat["p99"], 2),
        "queue_p50_ms": round(1e3 * lat.get("queue_p50", 0.0), 2),
        "hits": sum(len(r.hits) for r in reqs),
        "query_evals": evals["query"],
        "build_evals": evals["build"],
    }, indent=2))


if __name__ == "__main__":
    main()
