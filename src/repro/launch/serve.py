"""Retrieval serving driver — the paper's kind of serving: a sharded
subsequence-retrieval fleet answering batched queries.

  PYTHONPATH=src python -m repro.launch.serve --dataset proteins \
      --n-windows 2000 --shards 4 --queries 32 --eps 2.0

Builds per-shard reference nets (elastic, rendezvous-hashed), answers a
batch of range + type-II/III queries, reports pruning ratios and latency,
and exercises the straggler-work-stealing path with a simulated slow shard.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.matching import SubsequenceMatcher
from repro.data import synthetic
from repro.launch.elastic import ElasticIndex


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="proteins",
                    choices=["proteins", "songs", "traj"])
    ap.add_argument("--distance", default=None)
    ap.add_argument("--n-windows", type=int, default=2000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--eps", type=float, default=2.0)
    args = ap.parse_args()

    gen, default_dist = synthetic.DATASETS[args.dataset]
    dist = args.distance or default_dist or "erp"
    data = gen(args.n_windows, seed=0)
    rng = np.random.default_rng(1)

    workers = [f"worker{i}" for i in range(args.shards)]
    t0 = time.time()
    fleet = ElasticIndex(dist, data, workers, tight_bounds=True)
    build_s = time.time() - t0

    queries = data[rng.integers(0, len(data), args.queries)].copy()
    if data.dtype.kind == "i":
        flips = rng.random(queries.shape) < 0.1
        queries[flips] = rng.integers(0, queries.max() + 1, flips.sum())
    else:
        queries += rng.normal(scale=0.1, size=queries.shape).astype(
            queries.dtype)

    t0 = time.time()
    n_hits = 0
    for q in queries:
        n_hits += len(fleet.range_query(q, args.eps))
    serve_s = time.time() - t0
    evals = fleet.eval_count()
    naive = args.queries * len(data)

    # straggler mitigation: shard 0 is slow -> its queries are re-issued
    # against the replica fleet (here: a second ElasticIndex replica)
    replica = ElasticIndex(dist, data, workers, tight_bounds=True)
    t0 = time.time()
    stolen_hits = 0
    for q in queries:
        part = fleet.range_query(q, args.eps, dead=("worker0",))
        # "steal" worker0's share from the replica
        rep = replica.shards["worker0"]
        extra = [rep._global_ids[i]
                 for i in rep.range_query(q, args.eps)] if rep else []
        stolen_hits += len(sorted(set(part) | set(extra)))
    steal_s = time.time() - t0
    assert stolen_hits == n_hits, "work stealing must preserve exactness"

    # elastic resize: drop one worker, verify exactness is preserved
    frac = fleet.resize(workers[:-1])
    n_hits2 = sum(len(fleet.range_query(q, args.eps)) for q in queries)
    assert n_hits2 == n_hits, "resharding must preserve exactness"

    print(json.dumps({
        "dataset": args.dataset, "distance": dist,
        "windows": len(data), "shards": args.shards,
        "build_s": round(build_s, 2),
        "batch_queries": args.queries,
        "serve_s": round(serve_s, 3),
        "qps": round(args.queries / serve_s, 1),
        "hits": n_hits,
        "distance_evals": evals,
        "evals_vs_naive": round(evals / naive, 4),
        "steal_s": round(steal_s, 3),
        "resize_moved_frac": round(frac, 3),
    }, indent=2))


if __name__ == "__main__":
    main()
