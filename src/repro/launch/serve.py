"""Retrieval serving driver — the paper's kind of serving: a sharded
subsequence-retrieval fleet answering batched queries.

  PYTHONPATH=src python -m repro.launch.serve --dataset proteins \
      --n-windows 2000 --shards 4 --queries 32 --eps 2.0

  # or declaratively: the whole retrieval stack from one JSON config
  PYTHONPATH=src python -m repro.launch.serve --config fleet.json

``--config path.json`` deserializes straight into
:class:`~repro.retrieval.RetrievalConfig` (the file is exactly
``RetrievalConfig.to_json()`` output) and replaces the ad-hoc retrieval
flags (``--distance`` / ``--shards``); dataset and query-load flags stay.
The driver builds the fleet through the :class:`~repro.retrieval.Retriever`
facade, answers a batch of range queries on the stacked device path,
cross-checks the host per-shard loop, exercises dead-worker masking with a
replica work-steal, and resizes the fleet down one worker — printing
latency, pruning, and ``{query, build}`` accounting as JSON.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core.batch_engine import BatchEngine
from repro.data import synthetic
from repro.retrieval import RetrievalConfig, Retriever


def build_config(args) -> RetrievalConfig:
    """``--config path.json`` round-trips the declarative config; otherwise
    the legacy flags assemble the same dataclass."""
    if args.config:
        cfg = RetrievalConfig.from_json(
            pathlib.Path(args.config).read_text())
        if cfg.execution != "fleet":
            raise SystemExit(
                f"serve.py drives a fleet; config has "
                f"execution={cfg.execution!r}")
        return cfg
    _, default_dist = synthetic.DATASETS[args.dataset]
    return RetrievalConfig(
        distance=args.distance or default_dist or "erp",
        execution="fleet",
        workers=[f"worker{i}" for i in range(args.shards)],
        tight_bounds=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None,
                    help="path to a RetrievalConfig JSON (to_json output); "
                         "replaces --distance/--shards")
    ap.add_argument("--dataset", default="proteins",
                    choices=["proteins", "songs", "traj"])
    ap.add_argument("--distance", default=None)
    ap.add_argument("--n-windows", type=int, default=2000)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--eps", type=float, default=2.0)
    args = ap.parse_args()

    config = build_config(args)
    gen, _ = synthetic.DATASETS[args.dataset]
    data = gen(args.n_windows, seed=0)
    rng = np.random.default_rng(1)

    t0 = time.time()
    fleet = Retriever.build(config, data)
    build_s = time.time() - t0
    workers = fleet.elastic().workers

    queries = data[rng.integers(0, len(data), args.queries)].copy()
    if data.dtype.kind == "i":
        flips = rng.random(queries.shape) < 0.1
        queries[flips] = rng.integers(0, queries.max() + 1, flips.sum())
    else:
        queries += rng.normal(scale=0.1, size=queries.shape).astype(
            queries.dtype)

    # stacked device serving: the whole query batch is ONE fleet query
    # (merge_flats + one device dispatch per length bucket)
    t0 = time.time()
    batch_hits = fleet.batch(queries).range(args.eps)
    serve_s = time.time() - t0
    n_hits = sum(len(h) for h in batch_hits)

    # host per-shard loop: same hits, classic per-eval counting (the
    # paper's pruning-ratio currency lives in the counter's query bucket)
    t0 = time.time()
    loop_hits = fleet.batch(queries).via("host").range(args.eps)
    loop_s = time.time() - t0
    assert batch_hits.hits == loop_hits.hits, "stacked serving must stay exact"
    evals = fleet.eval_stats()
    naive = args.queries * len(data)

    # straggler mitigation: shard 0 is slow -> it is masked `dead` in the
    # stacked fleet query and its share re-issued against a replica
    replica = Retriever.build(config, data)
    t0 = time.time()
    part_hits = fleet.batch(queries).dead(workers[0]).range(args.eps)
    rep = replica.elastic().index.shards[workers[0]]
    if rep:
        # the replica answers the dead shard's share as ONE engine batch
        # (all stolen queries share a merged frontier round)
        stolen = BatchEngine(rep.net.counter).run(
            [rep.net.range_query_plan(args.eps) for _ in queries],
            list(queries), args.eps)
        extras = [[int(rep.gids[i]) for i in local] for local in stolen]
    else:
        extras = [[] for _ in queries]
    stolen_hits = sum(len(set(part) | set(extra))
                      for part, extra in zip(part_hits, extras))
    steal_s = time.time() - t0
    assert stolen_hits == n_hits, "work stealing must preserve exactness"

    # elastic resize: drop one worker, verify exactness is preserved and
    # the incremental reshard cost lands in the build bucket
    build_before = fleet.eval_stats()["build"]
    frac = fleet.elastic().resize(workers[:-1])
    resize_evals = fleet.eval_stats()["build"] - build_before
    n_hits2 = sum(len(h) for h in fleet.batch(queries).range(args.eps))
    assert n_hits2 == n_hits, "resharding must preserve exactness"

    print(json.dumps({
        "dataset": args.dataset, "distance": config.dist.name,
        "config": config.to_dict(),
        "windows": len(data), "shards": len(workers),
        "build_s": round(build_s, 2),
        "batch_queries": args.queries,
        "serve_s": round(serve_s, 3),
        "qps": round(args.queries / serve_s, 1),
        "loop_s": round(loop_s, 3),
        "loop_qps": round(args.queries / loop_s, 1),
        "hits": n_hits,
        "query_evals": evals["query"],
        "build_evals": evals["build"],
        "device_evals": fleet.elastic().device_stats["total_evals"],
        "evals_vs_naive": round(evals["query"] / naive, 4),
        "steal_s": round(steal_s, 3),
        "resize_moved_frac": round(frac, 3),
        "resize_build_evals": resize_evals,
    }, indent=2))


if __name__ == "__main__":
    main()
