"""Deterministic, shardable data pipeline with background prefetch.

* every batch is derived from (seed, step) — restart at step k reproduces
  the exact stream (checkpoint/resume safe, and data-parallel workers can
  slice their shard without coordination);
* a background thread keeps ``prefetch`` batches ready so host input never
  serializes with device compute;
* optional near-duplicate filtering through the paper's retrieval stack
  (windows of token ids indexed in a reference net; documents whose windows
  match an already-seen document within eps are dropped) — subsequence
  retrieval as a data-quality substrate.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class TokenBatcher:
    """(seed, step) -> {'tokens': (B, S), 'labels': (B, S)} int32."""

    def __init__(self, corpus: np.ndarray, batch: int, seq: int,
                 seed: int = 0, shard: int = 0, n_shards: int = 1):
        assert corpus.ndim == 2
        self.corpus = corpus
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.shard = shard
        self.n_shards = n_shards
        assert batch % n_shards == 0
        self.local_batch = batch // n_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        docs = rng.integers(0, len(self.corpus),
                            size=(self.batch,))
        starts = rng.integers(
            0, max(self.corpus.shape[1] - self.seq - 1, 1),
            size=(self.batch,))
        lo = self.shard * self.local_batch
        hi = lo + self.local_batch
        toks = np.stack([
            self.corpus[d, s:s + self.seq + 1]
            for d, s in zip(docs[lo:hi], starts[lo:hi])])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator."""

    def __init__(self, batcher: TokenBatcher, start_step: int = 0,
                 depth: int = 2):
        self.batcher = batcher
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.batcher.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def dedup_corpus(corpus: np.ndarray, *, lam: int = 16, eps: float = 1.0,
                 max_docs: Optional[int] = None) -> np.ndarray:
    """Drop near-duplicate documents using the paper's machinery: each doc's
    windows are range-queried against a reference net of all previously kept
    windows; a doc whose windows overwhelmingly hit is a near-duplicate."""
    from repro.core.batch_engine import BatchEngine
    from repro.core.counter import CountedDistance
    from repro.core.refnet import ReferenceNet
    from repro.core.segmentation import partition_windows
    from repro.distances import get

    dist = get("levenshtein")
    docs = corpus[:max_docs] if max_docs else corpus
    l = lam // 2
    kept = []
    net: Optional[ReferenceNet] = None
    data_rows = []
    for doc in docs:
        wins, _ = partition_windows([doc], lam)
        if net is None:
            kept.append(doc)
            data_rows = list(wins)
            net = ReferenceNet(dist, np.stack(data_rows), eps_prime=1.0,
                               tight_bounds=True).build()
            continue
        # one engine batch probes every window of the doc concurrently
        # (hit sets and eval counts match the sequential per-window loop)
        probe = BatchEngine(net.counter).run(
            [net.range_query_plan(eps) for _ in wins], list(wins), eps)
        hits = sum(bool(h) for h in probe)
        if hits >= max(1, int(0.9 * len(wins))):
            continue  # near-duplicate: drop
        kept.append(doc)
        base = len(data_rows)
        data_rows.extend(list(wins))
        # rebuild counter over the grown window set, then insert new windows
        net.counter = CountedDistance(dist, np.stack(data_rows))
        net.data = net.counter.data
        for i in range(base, len(data_rows)):
            net.insert(i)
    return np.stack(kept)
