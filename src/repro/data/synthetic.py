"""Synthetic corpora with matched statistics to the paper's datasets (§8).

The container is offline, so: PROTEINS -> alphabet-20 strings with planted
motifs (Levenshtein); SONGS -> integer pitch walks in [0, 11] (DFD's skewed
distance distribution emerges naturally); TRAJ -> 2-D random-walk
trajectories.  Window size l = 20 follows the paper.  Also provides token
corpora for LM training examples.
"""

from __future__ import annotations

from typing import List

import numpy as np


def proteins(n_windows: int, l: int = 20, alphabet: int = 20,
             n_motifs: int = 64, mutation: float = 0.15, seed: int = 0
             ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    motifs = rng.integers(0, alphabet, size=(n_motifs, l))
    data = motifs[rng.integers(0, n_motifs, n_windows)]
    mut = rng.random((n_windows, l)) < mutation
    return np.where(mut, rng.integers(0, alphabet, size=(n_windows, l)),
                    data).astype(np.int32)


def protein_sequences(n_seqs: int, length: int = 400, alphabet: int = 20,
                      n_motifs: int = 64, seed: int = 0) -> List[np.ndarray]:
    """Full sequences (for end-to-end subsequence matching) built by
    concatenating mutated motifs with random linkers."""
    rng = np.random.default_rng(seed)
    motifs = rng.integers(0, alphabet, size=(n_motifs, 20))
    seqs = []
    for _ in range(n_seqs):
        parts = []
        total = 0
        while total < length:
            if rng.random() < 0.7:
                m = motifs[rng.integers(0, n_motifs)].copy()
                mut = rng.random(len(m)) < 0.1
                m[mut] = rng.integers(0, alphabet, mut.sum())
                parts.append(m)
            else:
                parts.append(rng.integers(0, alphabet, size=(20,)))
            total += 20
        seqs.append(np.concatenate(parts)[:length].astype(np.int32))
    return seqs


def songs(n_windows: int, l: int = 20, seed: int = 0) -> np.ndarray:
    """Pitch sequences in [0, 11] — random walks with wraparound."""
    rng = np.random.default_rng(seed)
    steps = rng.integers(-2, 3, size=(n_windows, l))
    start = rng.integers(0, 12, size=(n_windows, 1))
    return ((start + np.cumsum(steps, axis=1)) % 12).astype(np.float32)


def trajectories(n_windows: int, l: int = 20, seed: int = 0) -> np.ndarray:
    """2-D parking-lot-style trajectories: smooth heading random walks."""
    rng = np.random.default_rng(seed)
    heading = np.cumsum(rng.normal(scale=0.3, size=(n_windows, l)), axis=1)
    speed = 0.5 + 0.2 * rng.random((n_windows, 1))
    dx = np.cos(heading) * speed
    dy = np.sin(heading) * speed
    xy = np.stack([np.cumsum(dx, 1), np.cumsum(dy, 1)], axis=-1)
    origin = rng.uniform(-10, 10, size=(n_windows, 1, 2))
    return (xy + origin).astype(np.float32)


def token_corpus(n_docs: int, doc_len: int, vocab: int, seed: int = 0,
                 dup_frac: float = 0.0) -> np.ndarray:
    """LM training corpus; optionally plants near-duplicate documents (for
    the retrieval-based dedup example)."""
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, vocab, size=(n_docs, doc_len), dtype=np.int32)
    n_dup = int(dup_frac * n_docs)
    for i in range(n_dup):
        src = rng.integers(0, n_docs)
        dst = rng.integers(0, n_docs)
        if src != dst:
            docs[dst] = docs[src]
            flips = rng.random(doc_len) < 0.02
            docs[dst, flips] = rng.integers(0, vocab, flips.sum())
    return docs


DATASETS = {
    "proteins": (proteins, "levenshtein"),
    "songs": (songs, None),          # used with dfd / erp
    "traj": (trajectories, None),    # used with dfd / erp
}
