"""Exact HLO cost extraction with while-loop trip-count weighting.

``compiled.cost_analysis()`` counts a while body ONCE, so a scan-over-layers
model under-reports FLOPs by ~n_layers x.  XLA annotates every while with
``backend_config={"known_trip_count":{"n":...}}``; this module parses the
compiled HLO text, builds the computation call graph (while bodies weighted
by their trip counts, fusions/calls by 1), and accumulates

* dot FLOPs        (2 x |result| x |contracting dims|),
* dot bytes        (lhs + rhs + result — the heavy HBM traffic),
* collective bytes (operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute).

Elementwise FLOPs/bytes are not counted (dots dominate every assigned
architecture); the §Roofline notes carry this caveat.

:func:`kernel_cost_report` complements the text parser with the compiler's
own ``cost_analysis()`` (which DOES count elementwise FLOPs and total bytes
accessed, but weights every while body once) so benchmarks can report the
arithmetic intensity of a compiled kernel — e.g. the O(B*L) elementwise
``lb:<name>`` envelope specs against the O(B*L^2) wavefront DP specs.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _split_operands(s: str) -> List[str]:
    """Split an operand list on top-level commas only — inline types like
    ``f32[64,128]{1,0}`` carry commas inside brackets/braces."""
    out, buf, depth = [], [], 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(buf).strip())
            buf = []
        else:
            buf.append(ch)
    if buf:
        out.append("".join(buf).strip())
    return out


def _shape_of(type_str: str) -> Tuple[Tuple[int, ...], int]:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return (), 0
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(d) for d in dims.split(",") if d)
    return shape, _DTYPE_BYTES.get(dt, 0)


def _nbytes(type_str: str) -> int:
    shape, b = _shape_of(type_str)
    n = 1
    for d in shape:
        n *= d
    return n * b


def parse_hlo_costs(hlo: str) -> Dict:
    """Returns {'flops', 'dot_bytes', 'collectives': {...}, 'n_while'}."""
    # --- split into computations -------------------------------------------
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY"):
            cur = "ENTRY"
            comps[cur] = []
            continue
        if ls.startswith("%") and ls.endswith("{"):
            cur = ls.split()[0].lstrip("%")
            comps[cur] = []
            continue
        if ls == "}":
            continue
        if cur is not None:
            comps[cur].append(ls)

    # --- per-computation: local defs, raw costs, call edges ------------------
    per: Dict[str, Dict] = {}
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, lines in comps.items():
        shapes: Dict[str, str] = {}
        for ls in lines:
            m = _DEF_RE.match(ls)
            if m:
                shapes[m.group(1)] = m.group(2)
        flops = 0.0
        dbytes = 0.0
        coll = {c: 0.0 for c in _COLLECTIVES}
        my_edges: List[Tuple[str, float]] = []
        for ls in lines:
            m = _DEF_RE.match(ls)
            if not m:
                continue
            rhs = m.group(2)
            res_type = rhs.split(" ", 1)[0]
            # call edges
            wm = re.search(r"\bwhile\(", rhs)
            if wm:
                trip = 1.0
                tm = _TRIP_RE.search(rhs)
                if tm:
                    trip = float(tm.group(1))
                bm = re.search(r"body=%?([\w.\-]+)", rhs)
                cm = re.search(r"condition=%?([\w.\-]+)", rhs)
                if bm:
                    my_edges.append((bm.group(1), trip))
                if cm:
                    my_edges.append((cm.group(1), trip))
                continue
            for kw in ("calls=", "to_apply="):
                km = re.search(kw + r"%?([\w.\-]+)", rhs)
                if km:
                    my_edges.append((km.group(1), 1.0))
            # dot costs
            dm = re.search(r"\bdot\(([^)]*)\)", rhs)
            if dm:
                ops = _split_operands(dm.group(1))
                op_types = []
                for o in ops[:2]:
                    o = o.lstrip("%")
                    # operand may carry an inline type or be a pure name
                    if "[" in o and not o.startswith("%"):
                        tok = o.split()
                        if _SHAPE_RE.match(tok[0]):
                            op_types.append(tok[0])
                            continue
                        o = tok[-1].lstrip("%")
                    ref = shapes.get(o, "")
                    op_types.append(ref.split(" ", 1)[0])
                res_shape, _ = _shape_of(res_type)
                lhs_shape, _ = _shape_of(op_types[0]) if op_types else ((), 0)
                cm2 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                contract = 1
                if cm2 and lhs_shape:
                    for d in cm2.group(1).split(","):
                        if d:
                            contract *= lhs_shape[int(d)]
                out_elems = 1
                for d in res_shape:
                    out_elems *= d
                flops += 2.0 * out_elems * contract
                dbytes += _nbytes(res_type)
                for t in op_types:
                    dbytes += _nbytes(t)
                continue
            # collectives
            for cname in _COLLECTIVES:
                if re.search(rf"\b{cname}\(", rhs):
                    args = rhs.split(f"{cname}(", 1)[1]
                    depth, buf = 1, []
                    for ch in args:
                        if ch == "(":
                            depth += 1
                        elif ch == ")":
                            depth -= 1
                            if depth == 0:
                                break
                        buf.append(ch)
                    inner = "".join(buf)
                    ops = [o.lstrip("%") for o in _split_operands(inner)]
                    nb = 0
                    for o in ops:
                        key = o.split()[-1].lstrip("%") if o else ""
                        ref = shapes.get(key, "")
                        if ref:
                            nb += _nbytes(ref.split(" ", 1)[0])
                        elif _SHAPE_RE.match(o):
                            nb += _nbytes(o.split()[0])
                    coll[cname] += nb
                    break
        per[name] = {"flops": flops, "dot_bytes": dbytes, "coll": coll}
        edges[name] = my_edges

    # --- propagate multipliers from ENTRY -----------------------------------
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult["ENTRY"] = 1.0
    for _ in range(16):  # call graphs are shallow DAGs
        changed = False
        new = {name: 0.0 for name in comps}
        new["ENTRY"] = 1.0
        for caller, es in edges.items():
            w = mult.get(caller, 0.0)
            if w == 0:
                continue
            for callee, t in es:
                if callee in new:
                    new[callee] += w * t
        for k in new:
            if abs(new[k] - mult[k]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break

    total = {"flops": 0.0, "dot_bytes": 0.0, "n_while": 0}
    coll_total = {c: 0.0 for c in _COLLECTIVES}
    for name, c in per.items():
        w = max(mult.get(name, 0.0), 0.0)
        if w == 0 and name != "ENTRY":
            continue
        w = max(w, 1.0) if name == "ENTRY" else w
        total["flops"] += w * c["flops"]
        total["dot_bytes"] += w * c["dot_bytes"]
        for k in _COLLECTIVES:
            coll_total[k] += w * c["coll"][k]
    total["n_while"] = sum(1 for es in edges.values() for _ in es)
    coll_total["total_bytes"] = sum(coll_total.values())
    total["collectives"] = coll_total
    return total


def band_intensity_report(Lx: int, Ly: int, d: int = 1, *, tile: int,
                          block_b: int = 8) -> Dict:
    """Analytic per-band arithmetic intensity of the wavefront schedules.

    Neither cost source in :func:`kernel_cost_report` can see the banding
    win: ``cost_analysis()`` weights while bodies once, and HLO text
    carries no VMEM-residency information.  This deterministic model
    compares the two schedules at the same work granularity — one band of
    ``tile`` anti-diagonals, ``tile * (Lx+1)`` DP cells:

    * **flops per band** — identical for both schedules (the banded kernel
      runs the exact same per-cell math): ``tile * (Lx+1) * c_cell`` with
      ``c_cell ~ 3d + 8`` (elementwise cost + sqrt + combine/clamp).
    * **bytes per band** — what each schedule must stage for that band.
      The tiled kernel stages only its ``(Lx + tile)``-wide reversed-y
      window (plus x, borders, and the carry diagonals riding scratch);
      the untiled schedule keeps the full ``2*Lx+Ly+1``-wide reversed-y
      operand resident for *any* stretch of diagonals.

    Per-band intensity of the untiled schedule therefore collapses
    ~``tile/(Lx+Ly)`` as segments grow, while the tiled kernel's is pinned
    by the VMEM budget — the whole point of the banding (and strictly
    above untiled for every ``tile <= Lx+Ly``).  Per-batch-row units
    (``block_b`` scales flops and bytes alike, so it cancels).
    """
    W = Lx + 1
    K = Lx + Ly
    T = max(1, min(int(tile), K))
    c_cell = 3 * d + 8
    flops_band = float(T * W * c_cell)

    def band_bytes(y_width: int) -> float:
        # f32 residency per band and batch row: x tile, the reversed-y
        # window (+ the ERP gap row riding next to it), border col + row,
        # two carry diagonals, answer/liveness columns
        return 4.0 * (W * d + y_width * (d + 1) + W + (Ly + 1)
                      + 2 * W + 4)

    tiled_bytes = band_bytes(Lx + T)
    untiled_bytes = band_bytes(2 * Lx + Ly + 1)
    return {
        "tile": T,
        "bands": -(-K // T),
        "flops_per_band": flops_band,
        "tiled_bytes_per_band": tiled_bytes,
        "untiled_bytes_per_band": untiled_bytes,
        "tiled_band_intensity": flops_band / tiled_bytes,
        "untiled_band_intensity": flops_band / untiled_bytes,
    }


def kernel_cost_report(fn, *args, band: Optional[Dict] = None) -> Dict:
    """Compile ``fn(*args)`` and report its roofline inputs.

    Combines two sources:

    * ``compiled.cost_analysis()`` — the compiler's own estimate; counts
      elementwise work and total HBM traffic (``flops`` / ``bytes``), but
      weights every while body ONCE, so iterative DPs under-report;
    * :func:`parse_hlo_costs` over the compiled HLO text — dot-only FLOPs
      with ``known_trip_count`` weighting plus the while count
      (``dot_flops`` / ``dot_bytes`` / ``n_while``), flagging when the
      single-visit caveat above actually bites.

    Returns ``{'flops', 'bytes', 'arithmetic_intensity', 'dot_flops',
    'dot_bytes', 'n_while'}``; compiler fields are 0.0 when the backend
    exposes no cost model (arithmetic intensity then reads 0.0 too).

    ``band`` (kwargs for :func:`band_intensity_report`, e.g. ``dict(Lx=24,
    Ly=24, d=2, tile=25)``) additionally merges the analytic per-band
    intensity of the tiled vs untiled wavefront schedule into the report —
    the banding effect neither compiled source can express.
    """
    import jax

    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # older jax wraps it in a list
        ca = ca[0] if ca else {}
    ca = dict(ca or {})
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    parsed = parse_hlo_costs(compiled.as_text())
    rep = {
        "flops": flops,
        "bytes": nbytes,
        "arithmetic_intensity": flops / nbytes if nbytes else 0.0,
        "dot_flops": parsed["flops"],
        "dot_bytes": parsed["dot_bytes"],
        "n_while": parsed["n_while"],
    }
    if band is not None:
        rep.update(band_intensity_report(**band))
    return rep
