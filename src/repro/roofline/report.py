"""§Roofline report generator: reads reports/dryrun/*.json, emits the
per-(arch x shape) three-term roofline table as markdown + JSON.

Terms (per DESIGN.md §7; TPU v5e constants):
  compute_s    = HLO dot FLOPs (trip-count-corrected) / (chips * 197e12)
  memory_s     = HBM floor = (argument+output bytes)/chip / 819e9
                 (upper bound from dot operand traffic also reported)
  collective_s = collective operand bytes (trip-corrected) / 50e9 per chip

``MODEL_FLOPS / HLO_FLOPs`` exposes remat & dispatch waste; dominant term =
argmax; roofline step time = max of terms (perfect overlap assumption);
MFU = MODEL_FLOPS / (chips * peak * step_time).

  PYTHONPATH=src python -m repro.roofline.report [--mesh pod16x16]
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional

from repro.configs.base import SHAPES
from repro.models import registry

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2**30  # v5e

REPORT_DIR = pathlib.Path(__file__).resolve().parents[3] / "reports"


def model_flops_for(cfg, shape) -> float:
    n = cfg.active_param_count()
    tokens = shape.seq_len * shape.global_batch
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def analyze(rec: dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    cfg, _ = registry.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    flops_dev = rec.get("hlo_dot_flops") or rec["cost"].get("flops", 0.0)
    mem = rec["memory"]
    hbm_floor = mem["argument_bytes"] + mem["output_bytes"]
    dot_bytes = rec.get("hlo_dot_bytes", 0.0)
    coll = rec["collectives"]["total_bytes"]
    mf = model_flops_for(cfg, shape)
    compute_s = flops_dev / PEAK_FLOPS
    mem_s = hbm_floor / HBM_BW
    mem_hi_s = dot_bytes / HBM_BW
    coll_s = coll / ICI_BW
    step = max(compute_s, mem_s, coll_s)
    terms = {"compute": compute_s, "memory": mem_s, "collective": coll_s}
    mfu = mf / (n_dev * PEAK_FLOPS * step) if step > 0 else 0.0
    total_dev_bytes = (mem["argument_bytes"] + mem["output_bytes"]
                       + mem["temp_bytes"] - mem["alias_bytes"])
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "flops_per_dev": flops_dev,
        "model_flops": mf,
        "useful_frac": mf / (flops_dev * n_dev) if flops_dev else 0.0,
        "compute_s": compute_s,
        "memory_s": mem_s,
        "memory_upper_s": mem_hi_s,
        "collective_s": coll_s,
        "dominant": max(terms, key=terms.get),
        "step_s": step,
        "mfu": mfu,
        "hbm_gib": total_dev_bytes / 2**30,
        "fits_v5e": total_dev_bytes <= HBM_PER_CHIP,
    }


def load_all(mesh: str) -> List[Dict]:
    rows = []
    for p in sorted((REPORT_DIR / "dryrun").glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": rec["reason"]})
            continue
        r = analyze(rec)
        if r:
            rows.append(r)
        else:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "error": rec.get("error", "?")})
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | coll s | dominant | "
           "step s | MFU | useful FLOPs | HBM GiB | fits v5e |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skip (sub-quadratic only) | — | — | — | — | — |\n")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                       f"{r['error'][:40]} |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['step_s']:.3f} | {r['mfu']:.1%} | "
            f"{r['useful_frac']:.1%} | {r['hbm_gib']:.1f} | "
            f"{'yes' if r['fits_v5e'] else 'NO'} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    rows = load_all(args.mesh)
    md = to_markdown(rows)
    print(md)
    (REPORT_DIR / f"roofline_{args.mesh}.json").write_text(
        json.dumps(rows, indent=2))
    (REPORT_DIR / f"roofline_{args.mesh}.md").write_text(md)
    print(f"# wrote reports/roofline_{args.mesh}.{{json,md}}")


if __name__ == "__main__":
    main()
