"""The Reference Net (paper §6 + Appendix A) — host-mode implementation.

A hierarchical metric index with levels ``i = 0 .. r-1``:

* level radius ``eps_i = eps' * 2**i``;
* *inclusive*: every reference at level i-1 is within ``eps_i`` of at least
  one level-i reference (it has >= 1 parent);
* *exclusive*: two references at the same level i are > ``eps_i`` apart;
* a node may have **multiple parents** (the net/tree distinction of Fig. 2),
  capped at ``num_max`` to keep space linear;
* the bottom layer holds *all* database objects: an object within ``eps_0``
  of some level-0 reference is stored as a plain member of that reference's
  list, otherwise it becomes a level-0 (or higher) reference itself;
* each reference is stored once, at its highest level (paper §6), and each
  list link records the (conceptual) level at which it was formed — in the
  paper a reference has a separate list per level it appears at; recording
  the attach level preserves those per-level radii in flattened storage.

Range queries implement Algorithm 3 / Lemma 4 as *bound propagation*: every
processed reference R with known d = delta(Q, R) contributes, through each
of its list links, an interval for the child and for the child's whole
derived subtree:

    d(Q, c)        in  [d - r_link,        d + r_link]
    d(Q, subtree)  in  [d - r_link - sr_c, d + r_link + sr_c]

where, in **faithful** mode (the paper's Lemma 4), ``r_link = eps_i`` of the
attach level and ``sr_c = eps_{level(c)+1}``; in **tight** mode (a
beyond-paper refinement, cf. M-tree) ``r_link`` is the exact stored link
distance and ``sr_c`` the exact maintained subtree radius.  With multiple
parents the intervals *intersect* — this is precisely the Fig. 2 advantage:
every additional parent is another chance to decide a child for free.
Children are resolved lazily (objects at the very end, expandable references
just before their own level), so every parent that gets processed
contributes its bound before any distance evaluation is spent.

All distance evaluations go through :class:`CountedDistance`, so pruning
ratios reported by the benchmarks are exact evaluation counts.

Construction mirrors querying: Alg. 1's widened descent is a frontier
*plan* (:meth:`ReferenceNet.insert_plan`) that yields per-level candidate
batches and returns a pure :class:`InsertOutcome`; ``insert`` drives one
plan sequentially (classic counts), while :meth:`ReferenceNet.build_batched`
drives whole cohorts of plans through the batch engine and commits them
after order-rank conflict arbitration — same invariants and hit sets, far
fewer backend dispatches.  Build-time evaluations are charged to the
counter's ``build`` bucket, never to the paper's query currency.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import batch_engine
from repro.core.counter import CountedDistance
from repro.distances import base as dist_base

OBJ = -1  # pseudo-level of plain (non-reference) objects
INF = float("inf")


@dataclasses.dataclass
class InsertOutcome:
    """Result of an :meth:`ReferenceNet.insert_plan` descent.

    A pure description of *where* object ``idx`` lands — the plan never
    mutates the net, so many plans can run concurrently against one
    snapshot and be committed (or re-planned) afterwards by the bulk
    loader's arbitration."""
    idx: int
    new_top: int                 # required root level (>= top at plan time)
    target_level: int            # stored level of the new node (OBJ = member)
    attach_level: int            # conceptual level of the new links
    owners: Dict[int, float]     # candidate parents -> exact distance


@dataclasses.dataclass
class Node:
    idx: int                   # row in the data array
    level: int                 # highest level at which this node is a reference
    children: List[int]        # node idxs appearing in my list
    child_dist: List[float]    # exact delta(me, child) per link
    child_level: List[int]     # conceptual level the link was formed at
    parents: List[int]         # up-links (multi-parent; len <= num_max)
    sub_radius: float = 0.0    # exact derived-subtree radius (maintained)


class ReferenceNet:
    """Host-mode reference net over a fixed-length window database.

    Args:
      tight_bounds: False = paper-faithful Lemma-4 radii (eps powers);
        True = exact link distances / subtree radii (beyond-paper, strictly
        tighter, same O(n) space).
    """

    def __init__(self, dist, data: np.ndarray, *,
                 eps_prime: float = 1.0, num_max: Optional[int] = None,
                 tight_bounds: bool = False,
                 counter: Optional[CountedDistance] = None):
        # registry name or Distance instance, interchangeably
        self.dist = dist_base.require_metric(dist)
        self.eps_prime = float(eps_prime)
        self.num_max = num_max
        self.tight_bounds = tight_bounds
        self.counter = counter or CountedDistance(self.dist, data)
        self.data = self.counter.data
        self.nodes: Dict[int, Node] = {}
        self.root: Optional[int] = None
        self.top_level: int = 0

    # -- radii ------------------------------------------------------------

    def eps(self, i: int) -> float:
        """Level radius eps_i = eps' * 2**i  (eps_{OBJ} treated as 0)."""
        if i < 0:
            return 0.0
        return self.eps_prime * (2.0 ** i)

    def _link_radius(self, node: Node, k: int) -> float:
        if self.tight_bounds:
            return node.child_dist[k]
        return self.eps(node.child_level[k])

    def _subtree_radius(self, node: Node) -> float:
        if not node.children:
            return 0.0
        if self.tight_bounds:
            return node.sub_radius
        return self.eps(node.level + 1)

    # -- construction -------------------------------------------------------

    def build(self, order: Optional[Sequence[int]] = None) -> "ReferenceNet":
        """Sequential loader (one insert-plan descent per object); see
        :meth:`build_batched` for the cohort bulk loader."""
        idxs = range(len(self.data)) if order is None else order
        for i in idxs:
            self.insert(i)
        return self

    def extend_data(self, rows: np.ndarray) -> List[int]:
        """Append fresh windows to the net's database without touching the
        built structure; returns their new row indices.

        The rows are *not* inserted — feed the returned indices to
        :meth:`build_batched` (``order=new_ids``) to bulk-load them through
        the cohort pipeline against the existing net.  This is the elastic
        layer's reshard-in path: a shard that gains windows extends and
        bulk-loads instead of rebuilding from scratch."""
        rows = np.asarray(rows)
        base = len(self.counter.data)
        self.counter.extend(rows)
        self.data = self.counter.data
        return list(range(base, base + len(rows)))

    def insert(self, idx: int) -> None:
        """Insert object ``idx``: the sequential ``drive()`` of
        :meth:`insert_plan` — evaluation counts and the resulting structure
        are bit-identical to the historical pair-at-a-time descent."""
        if self.root is None:
            self.root = idx
            self.top_level = 0
            self.nodes[idx] = Node(idx, 0, [], [], [], [])
            return
        out = batch_engine.drive(self.insert_plan(idx), self.counter,
                                 self.data[idx])
        self._apply_insert(out)

    def insert_plan(self, idx: int) -> batch_engine.Plan:
        """Alg. 1's widened descent as a frontier plan (same Frontier/send
        protocol as :meth:`range_query_plan`, build-bucket accounting).

        Yields per-level EXACT frontiers of reference idxs, receives their
        distances to ``data[idx]``, and returns an :class:`InsertOutcome`
        describing where the object lands — without mutating the net, so
        ``build_batched`` can run whole cohorts of these concurrently
        against one snapshot and arbitrate conflicts before committing.
        """
        assert self.root is not None, "seed the net with one insert() first"
        ds = yield batch_engine.Frontier(
            np.asarray([self.root], np.int64), batch_engine.EXACT,
            bucket=batch_engine.BUILD)
        d_root = float(ds[0])
        # the root's level must grow until it covers the new point; recorded
        # in the outcome and applied at commit time
        top = self.top_level
        while d_root > self.eps(top):
            top += 1

        # descend, keeping the *wide* frontier: refs with d <= 2*eps_i; any
        # same-level conflict below is reachable through such ancestors
        # (chain bound: eps_l + sum_{t=l+1..i} eps_t <= 2*eps_i).
        frontier: Dict[int, float] = {self.root: d_root}
        parents_at: Dict[int, Dict[int, float]] = {}
        level = top
        parents_at[level] = {
            n: d for n, d in frontier.items() if d <= self.eps(level)}
        while level > 0:
            cand: Set[int] = set()
            for n in frontier:
                for c in self.nodes[n].children:
                    if c in self.nodes and self.nodes[c].level == level - 1:
                        cand.add(c)
                # a reference conceptually appears at every level below its
                # top; keep it in the running frontier
                cand.add(n)
            cand_new = [c for c in cand if c not in frontier]
            dists: Dict[int, float] = {}
            if cand_new:
                ds = yield batch_engine.Frontier(
                    np.asarray(cand_new, np.int64), batch_engine.EXACT,
                    bucket=batch_engine.BUILD)
                dists.update(zip(cand_new, map(float, ds)))
            dists.update({c: frontier[c] for c in cand if c in frontier})
            level -= 1
            frontier = {c: d for c, d in dists.items()
                        if d <= 2.0 * self.eps(level)}
            parents_at[level] = {
                c: d for c, d in dists.items() if d <= self.eps(level)}
            if not frontier:
                break

        # Alg. 1 "jumps to the lowest possible level": X becomes a reference
        # one level below the lowest covered level m.  Exclusivity at m-1 is
        # guaranteed: any level-(m-1) conflict would have been discovered
        # through the wide frontier.
        m = None
        for l in range(0, top + 1):
            if parents_at.get(l):
                m = l
                break
        assert m is not None, "root must cover the new point after growth"
        if m == 0:
            # within eps_0 of a level-0 reference -> plain object (bottom)
            return InsertOutcome(idx, top, OBJ, 0, parents_at[0])
        return InsertOutcome(idx, top, m - 1, m, parents_at[m])

    def _apply_insert(self, out: InsertOutcome) -> None:
        """Commit a planned insert: grow the root, then attach."""
        while self.top_level < out.new_top:
            self.top_level += 1
            self.nodes[self.root].level = self.top_level
        self._attach(out.idx, out.target_level, out.owners,
                     attach_level=out.attach_level)

    def build_batched(self, order: Optional[Sequence[int]] = None, *,
                      max_cohort: int = 256,
                      engine: Optional["batch_engine.BatchEngine"] = None
                      ) -> "ReferenceNet":
        """Level-synchronous bulk loader: cohorts of concurrent insert plans.

        Each round takes a cohort of not-yet-inserted objects, runs all
        their :meth:`insert_plan` descents against the *current* net through
        the :class:`~repro.core.batch_engine.BatchEngine` (pairwise mode —
        one merged dispatch per descent level instead of one per object per
        level), then commits the outcomes.  Two cohort members that would
        both become references at the same level may violate the exclusive
        property; :meth:`_commit_cohort` detects those pairs with one
        batched dispatch and resolves them by deterministic order-rank
        arbitration — the earlier object in ``order`` wins, the loser is
        re-planned in the next cohort against the updated net (where it
        typically lands *under* the winner).  The result passes
        ``check_invariants()`` and returns identical range-query hit sets
        to a sequentially built net, with far fewer backend dispatches
        (``counter.build_dispatches``; see ``benchmarks/bench_build.py``).

        Cohort sizes double from 4 up to ``max_cohort`` — the early net is
        coarse and conflict-prone, the late net absorbs large cohorts with
        almost no arbitration.
        """
        idxs = list(range(len(self.data))) if order is None else \
            [int(i) for i in order]
        rank = {x: r for r, x in enumerate(idxs)}
        pending = [i for i in idxs if i not in self.nodes]
        if self.root is None and pending:
            self.insert(pending.pop(0))
        eng = engine or batch_engine.BatchEngine(self.counter)
        cohort = 4
        while pending:
            take, pending = pending[:cohort], pending[cohort:]
            plans = [self.insert_plan(i) for i in take]
            outs = eng.run(plans, np.asarray(take, np.int64), eps=0.0)
            deferred = self._commit_cohort(outs, rank)
            pending = deferred + pending
            cohort = min(2 * cohort, max_cohort)
        return self

    def _commit_cohort(self, outs: Sequence[InsertOutcome],
                       rank: Dict[int, int]) -> List[int]:
        """Commit one cohort's outcomes; return the re-plan (loser) idxs.

        Conflicts only arise between two *new* references at the same
        stored level (each plan's wide frontier already rules out conflicts
        with snapshot references), so it suffices to evaluate intra-cohort
        same-level pairs — one batched dispatch — and accept greedily in
        order-rank."""
        outs = sorted(outs, key=lambda o: rank[o.idx])
        groups: Dict[int, List[int]] = {}
        for o in outs:
            if o.target_level >= 0:
                groups.setdefault(o.target_level, []).append(o.idx)
        pairs = [(a, b) for grp in groups.values()
                 for i, a in enumerate(grp) for b in grp[i + 1:]]
        pair_d: Dict[Tuple[int, int], float] = {}
        if pairs:
            ds = self.counter.eval_pairs([a for a, _ in pairs],
                                         [b for _, b in pairs])
            pair_d = {p: float(d) for p, d in zip(pairs, ds)}
        accepted: List[InsertOutcome] = []
        deferred: List[int] = []
        winners_at: Dict[int, List[int]] = {}
        for o in outs:
            if o.target_level >= 0:
                eps_l = self.eps(o.target_level)
                if any(pair_d[(w, o.idx)] <= eps_l
                       for w in winners_at.get(o.target_level, ())):
                    deferred.append(o.idx)
                    continue
                winners_at.setdefault(o.target_level, []).append(o.idx)
            accepted.append(o)
        for o in accepted:
            self._apply_insert(o)
        return deferred

    def _attach(self, idx: int, level: int, owners: Dict[int, float],
                attach_level: int) -> None:
        assert owners, "inclusive property would be violated"
        ranked = sorted(owners.items(), key=lambda kv: kv[1])
        if self.num_max is not None:
            ranked = ranked[: self.num_max]
        node = Node(idx, level, [], [], [], [p for p, _ in ranked])
        self.nodes[idx] = node
        for p, d in ranked:
            pn = self.nodes[p]
            pn.children.append(idx)
            pn.child_dist.append(d)
            pn.child_level.append(attach_level)
            self._grow_radius(p, d)  # node.sub_radius starts at 0

    def _grow_radius(self, p: int, new_r: float) -> None:
        """Propagate an enlarged subtree radius up the parent DAG.

        Iterative (explicit stack): multi-parent DAGs built from large n can
        be deep enough that the recursive form hits Python's recursion
        limit; the <=-check still cuts every already-covered branch."""
        stack = [(p, new_r)]
        while stack:
            x, r = stack.pop()
            xn = self.nodes[x]
            if r <= xn.sub_radius:
                continue
            xn.sub_radius = r
            for gp in xn.parents:
                gpn = self.nodes.get(gp)
                if gpn is None:
                    continue
                k = gpn.children.index(x)
                stack.append((gp, gpn.child_dist[k] + r))

    # -- deletion (Alg. 2) --------------------------------------------------

    def delete(self, idx: int) -> None:
        node = self.nodes.pop(idx)
        if idx == self.root:
            raise NotImplementedError("root deletion requires re-rooting")
        for p in node.parents:
            pn = self.nodes.get(p)
            if pn is not None:
                k = pn.children.index(idx)
                del pn.children[k], pn.child_dist[k], pn.child_level[k]
        # re-home orphaned members of X's list (Alg. 2: if a member still
        # appears in another list we do nothing, else re-insert it)
        orphans = []
        for k, c in enumerate(node.children):
            cn = self.nodes.get(c)
            if cn is None:
                continue
            cn.parents.remove(idx)
            if not cn.parents:
                orphans.append(c)
        for c in orphans:
            cn = self.nodes.pop(c)
            sub = [(g, cn.child_dist[k], cn.child_level[k])
                   for k, g in enumerate(cn.children)]
            self.insert(c)
            new_cn = self.nodes[c]
            for g, gd, gl in sub:
                gn = self.nodes.get(g)
                if gn is not None:
                    new_cn.children.append(g)
                    new_cn.child_dist.append(gd)
                    new_cn.child_level.append(gl)
                    gn.parents.append(c)
                    self._grow_radius(c, gd + gn.sub_radius)

    # -- range query (Alg. 3 as bound propagation) ---------------------------

    def range_query(self, q: np.ndarray, eps: float,
                    q_len: Optional[int] = None, *,
                    lb_cascade=False) -> List[int]:
        """All object idxs X with delta(q, X) <= eps (host-mode driver)."""
        return batch_engine.drive(self.range_query_plan(eps), self.counter,
                                  q, q_len, eps=eps, lb_cascade=lb_cascade)

    def range_query_plan(self, eps: float) -> batch_engine.Plan:
        """Algorithm 3 as a frontier generator (see ``core/batch_engine.py``).

        Yields batches of undecided candidates, receives their distances,
        returns the sorted hit list.  The frontier sequence — and therefore
        the exact-evaluation count — is identical to the classic host path;
        only *who* evaluates a frontier (sequential driver vs the batched
        engine merging many plans per round) changes.
        """
        if self.root is None:
            return []
        known: Dict[int, float] = {}   # exact distances (each counted once)
        lo: Dict[int, float] = {}      # accumulated object lower bounds
        hi: Dict[int, float] = {}      # accumulated object upper bounds
        slo: Dict[int, float] = {}     # subtree lower bounds
        shi: Dict[int, float] = {}     # subtree upper bounds
        closed: Set[int] = set()       # whole-subtree verdict settled
        decided: Set[int] = set()      # object verdict settled
        results: List[int] = []

        def request(idxs, kind):
            # de-dup against known, then yield ONE frontier for the batch
            new = sorted(set(i for i in idxs if i not in known))
            if new:
                ds = yield batch_engine.Frontier(np.asarray(new, np.int64),
                                                 kind)
                known.update(zip(new, map(float, ds)))

        def settle_subtree(n: int, accept: bool) -> None:
            stack = [n]
            while stack:
                x = stack.pop()
                if x in closed:
                    continue
                closed.add(x)
                if x not in decided:
                    decided.add(x)
                    if accept:
                        results.append(x)
                stack.extend(self.nodes[x].children)

        def decide(x: int, inside: bool) -> None:
            if x in decided:
                return
            decided.add(x)
            if inside:
                results.append(x)

        yield from request([self.root], batch_engine.EXACT)
        d_root = known[self.root]
        decide(self.root, d_root <= eps)
        alive: Set[int] = {self.root}
        pending_leaf: Set[int] = set()     # objects awaiting final verdict

        for level in range(self.top_level, -1, -1):
            # evaluate deferred expandable children whose level is reached;
            # exact values feed Lemma-4 bound propagation below
            defer = [c for c in alive
                     if c not in known and c not in closed
                     and self.nodes[c].level == level]
            yield from request(defer, batch_engine.EXACT)
            for c in defer:
                d = known[c]
                decide(c, d <= eps)

            for n in sorted(c for c in alive
                            if self.nodes[c].level == level):
                alive.discard(n)
                if n in closed:
                    continue
                node = self.nodes[n]
                d = known[n]
                sr = self._subtree_radius(node)
                if d + sr <= eps:
                    settle_subtree(n, accept=True)
                    continue
                if d - sr > eps:
                    # n itself was decided exactly; only descendants settle
                    for c in node.children:
                        settle_subtree(c, accept=False)
                    closed.add(n)
                    continue
                for k, c in enumerate(node.children):
                    if c in closed:
                        continue
                    cn = self.nodes.get(c)
                    if cn is None:
                        continue
                    r = self._link_radius(node, k)
                    src = self._subtree_radius(cn)
                    lo[c] = max(lo.get(c, 0.0), d - r)
                    hi[c] = min(hi.get(c, INF), d + r)
                    slo[c] = max(slo.get(c, 0.0), d - r - src)
                    shi[c] = min(shi.get(c, INF), d + r + src)
                    if shi[c] <= eps:
                        settle_subtree(c, accept=True)
                        continue
                    if slo[c] > eps:
                        settle_subtree(c, accept=False)
                        continue
                    if hi[c] <= eps:
                        decide(c, True)
                    elif lo[c] > eps:
                        decide(c, False)
                    if cn.children:
                        alive.add(c)       # expandable: deferred to its level
                    elif c not in decided:
                        pending_leaf.add(c)
                closed.add(n)

        # final object verdicts for leaves no parent managed to decide free;
        # only the <= eps verdict is consumed, so the LB cascade may prune
        rem = [c for c in pending_leaf if c not in decided and c not in closed]
        yield from request(rem, batch_engine.VERDICT)
        for c in rem:
            decide(c, known[c] <= eps)
        return sorted(results)

    def _subtree(self, n: int, include_self: bool = True) -> List[int]:
        out = [n] if include_self else []
        stack = list(self.nodes[n].children)
        seen = set(stack)
        while stack:
            c = stack.pop()
            out.append(c)
            cn = self.nodes.get(c)
            if cn:
                for g in cn.children:
                    if g not in seen:
                        seen.add(g)
                        stack.append(g)
        return out

    # -- invariants & stats (used by tests / benchmarks) ----------------------

    def check_invariants(self) -> None:
        levels: Dict[int, List[int]] = {}
        for n in self.nodes.values():
            levels.setdefault(n.level, []).append(n.idx)
        # exclusive
        for l, members in levels.items():
            if l < 0 or len(members) < 2:
                continue
            eps_l = self.eps(l)
            for a_i, a in enumerate(members):
                rest = members[a_i + 1:]
                if not rest:
                    continue
                ds = np.asarray(self.counter._batch(
                    np.repeat(self.data[a][None], len(rest), 0),
                    self.data[rest]))
                if np.any(ds <= eps_l):
                    bad = rest[int(np.argmax(ds <= eps_l))]
                    raise AssertionError(
                        f"exclusive violated at level {l}: {a} vs {bad}")
        # inclusive + link metadata consistency
        for n in self.nodes.values():
            if n.idx != self.root:
                assert n.parents, f"node {n.idx} has no parent"
                if self.num_max is not None:
                    assert len(n.parents) <= self.num_max
            for k, c in enumerate(n.children):
                cn = self.nodes.get(c)
                if cn is None:
                    continue
                d = float(self.counter._batch(
                    self.data[n.idx][None], self.data[c][None])[0])
                assert abs(d - n.child_dist[k]) <= 1e-3, \
                    f"stored link distance wrong for {n.idx}->{c}"
                assert d <= self.eps(n.child_level[k]) + 1e-4, \
                    f"link {n.idx}->{c} exceeds its attach-level radius"
        # subtree radii are genuine upper bounds
        for n in self.nodes.values():
            sub = self._subtree(n.idx, include_self=False)
            if not sub:
                continue
            ds = np.asarray(self.counter._batch(
                np.repeat(self.data[n.idx][None], len(sub), 0),
                self.data[sub]))
            assert np.all(ds <= n.sub_radius + 1e-3), \
                f"sub_radius understates subtree extent at {n.idx}"
            assert np.all(ds <= self.eps(n.level + 1) + 1e-3), \
                f"Lemma-4 radius violated at {n.idx}"
        # reachability
        reach = set(self._subtree(self.root))
        missing = set(self.nodes) - reach
        assert not missing, f"unreachable nodes: {sorted(missing)[:5]}"

    def stats(self) -> Dict[str, float]:
        n_list_entries = sum(len(n.children) for n in self.nodes.values())
        n_refs = sum(1 for n in self.nodes.values() if n.level >= 0)
        parents = [len(n.parents) for n in self.nodes.values()
                   if n.idx != self.root]
        return {
            "n_objects": len(self.nodes),
            "n_references": n_refs,
            "n_levels": self.top_level + 1,
            "n_list_entries": n_list_entries,
            "avg_parents": float(np.mean(parents)) if parents else 0.0,
            "max_parents": int(np.max(parents)) if parents else 0,
            # per link: child idx (8B) + distance (4B) + level (4B); per node:
            # idx/level/radius/record overhead ~24B
            "size_bytes": 16 * n_list_entries + 24 * len(self.nodes),
        }
