"""Reference-based indexing baseline (Venkateswaran et al., VLDB'06).

The paper's other comparison point: pick ``k`` references, precompute the
full (k x N) distance table, and prune with the triangle inequality
|d(Q, r) - d(r, X)| > eps  =>  d(Q, X) > eps.  Space is O(kN) — the paper's
point is that the reference net achieves better pruning with O(N) space.

Reference selection uses the Maximum Variance heuristic (paper §8.2 uses MV
because Maximum Pruning needs a training query set): greedily pick the
candidate whose distance vector over a sample has maximal variance,
discounting redundancy with already-picked references.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import batch_engine
from repro.core.counter import CountedDistance
from repro.distances import base as dist_base


class MVReferenceIndex:
    def __init__(self, dist, data: np.ndarray, *,
                 n_refs: int = 5, sample: int = 256, seed: int = 0,
                 counter: Optional[CountedDistance] = None):
        # registry name or Distance instance, interchangeably
        self.dist = dist_base.require_metric(dist)
        self.counter = counter or CountedDistance(self.dist, data)
        self.data = self.counter.data
        self.n_refs = n_refs
        self._rng = np.random.default_rng(seed)
        self._sample = sample
        self.refs: List[int] = []
        self.table: Optional[np.ndarray] = None  # (n_refs, N)

    def build(self) -> "MVReferenceIndex":
        """Stacked bulk construction: the candidate-profile and table loops
        are (candidate x sample) and (reference x N) pairwise blocks, each
        assembled in one ``eval_pairs`` dispatch (chunked only to bound the
        wavefront's working set) and charged to the counter's ``build``
        bucket — query-time accounting starts at zero without a reset."""
        N = len(self.data)
        cand = self._rng.choice(N, size=min(4 * self.n_refs, N), replace=False)
        samp = self._rng.choice(N, size=min(self._sample, N), replace=False)
        # variance of each candidate's distance profile over the sample
        profiles = self._pair_block(cand, samp)
        scores = profiles.var(axis=1)
        order = np.argsort(scores)[::-1]
        picked: List[int] = []
        for o in order:
            if len(picked) >= self.n_refs:
                break
            # redundancy discount: skip candidates highly correlated with
            # an already-picked reference profile
            if any(np.corrcoef(profiles[o], profiles[p])[0, 1] > 0.95
                   for p in picked):
                continue
            picked.append(int(o))
        while len(picked) < self.n_refs:
            extra = [int(o) for o in order if int(o) not in picked]
            if not extra:
                break
            picked.append(extra[0])
        self.refs = [int(cand[p]) for p in picked]
        self.table = self._pair_block(np.asarray(self.refs, np.int64),
                                      np.arange(N, dtype=np.int64))
        return self

    #: rows per build dispatch — bounds the numpy wavefront's (B, Lx, Ly)
    #: cost tensor while keeping dispatch counts O(k*N / cap), not O(k)
    _CHUNK_ROWS = 1 << 17

    def _pair_block(self, lefts: np.ndarray, rights: np.ndarray
                    ) -> np.ndarray:
        """(len(lefts), len(rights)) distance block via stacked dispatches."""
        ll = np.repeat(np.asarray(lefts, np.int64), len(rights))
        rr = np.tile(np.asarray(rights, np.int64), len(lefts))
        out = np.empty(ll.size, np.float32)
        for s in range(0, ll.size, self._CHUNK_ROWS):
            e = min(s + self._CHUNK_ROWS, ll.size)
            out[s:e] = self.counter.eval_pairs(ll[s:e], rr[s:e])
        return out.reshape(len(lefts), len(rights))

    def range_query(self, q: np.ndarray, eps: float,
                    q_len: Optional[int] = None, *,
                    lb_cascade=False) -> List[int]:
        return batch_engine.drive(self.range_query_plan(eps), self.counter,
                                  q, q_len, eps=eps, lb_cascade=lb_cascade)

    def range_query_plan(self, eps: float) -> batch_engine.Plan:
        """Two-frontier plan: reference row (exact, feeds the triangle-
        inequality table pruning), then the survivors (verdict only)."""
        assert self.table is not None, "call build() first"
        dq = yield batch_engine.Frontier(np.asarray(self.refs, np.int64),
                                         batch_engine.EXACT)  # k evals
        lower = np.max(np.abs(np.asarray(dq)[:, None] - self.table), axis=0)
        surv = np.nonzero(lower <= eps)[0]
        if surv.size == 0:
            return []
        dd = yield batch_engine.Frontier(surv, batch_engine.VERDICT)
        return sorted(int(i) for i in surv[np.asarray(dd) <= eps])

    def stats(self) -> dict:
        return {
            "n_objects": len(self.data),
            "n_refs": self.n_refs,
            "table_entries": int(self.table.size) if self.table is not None else 0,
            "size_bytes": 4 * int(self.table.size) if self.table is not None else 0,
        }
