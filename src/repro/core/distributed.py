"""Device-mode (TPU-native) retrieval: the reference net flattened into
dense arrays + a shard_map fleet query (DESIGN.md §4.2/§4.3).

Host mode chases pointers; accelerators want dense batched work.  The net
is flattened at a pivot level m: every reference with level >= m becomes a
*pivot*; every window belongs to exactly one pivot's member list (its
parent chain's level-m ancestor), carrying its exact link distance.  A
batched range query is then:

  1. one wavefront-kernel call: queries x pivots distances  (Q, P);
  2. triangle-inequality verdicts per pivot:
       d + sub_radius <= eps  -> accept all members free,
       d - sub_radius >  eps  -> prune all members free;
  3. per-member ring bound |d(q,pivot) - d(pivot,w)| > eps prunes members
     of undecided pivots elementwise (free — the link distances are dense
     arrays);
  4. survivors are *compacted* (jnp.nonzero with a static capacity) and
     evaluated in one batched kernel call.

Pruning therefore saves real compute and HBM traffic, not just a counter —
the static capacity is the TPU translation of data-dependent work.  The
fleet version shard_maps this over the data axis (stacked per-shard arrays)
with queries replicated; results are exact unions, since shards partition
the windows.

Since PR 6 this one-shot stacked fleet query is the elastic layer's
*fallback* serving mode (``ElasticIndex(..., fleet_mode="oneshot")``): it
pays exactly one device dispatch per batch, but only the flat pivot/ring
bounds prune.  The default fleet path is round-based — shard-local
frontier plans merged per round through the packed fused-ε dispatcher
(``core/batch_engine.FleetBatchEngine`` + ``kernels/dispatch.py``) — which
keeps the reference net's full pruning power (see ``launch/elastic.py``
and ``docs/architecture.md``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import _deprecation
from repro.core.refnet import ReferenceNet
from repro.distances import bounds
from repro.distances import np_backend
from repro.kernels import registry as kernel_registry


@dataclasses.dataclass
class FlatNet:
    """Flattened (pivot -> members) arrays; all padded to static shapes."""
    pivots: np.ndarray          # (P, l[, d]) pivot windows
    pivot_radius: np.ndarray    # (P,) exact derived-subtree radius
    members: np.ndarray         # (P, M) window ids, -1 padding
    member_dist: np.ndarray     # (P, M) exact delta(pivot, member)
    data: np.ndarray            # (N, l[, d]) all windows
    n_pivots: int
    dist_name: str
    pivot_ids: Optional[np.ndarray] = None   # (P,) window id of each pivot
    #: precomputed per-window envelope statistics (boxes + ERP gap masses;
    #: ``distances/bounds.py``), built in ONE stacked pass at flatten time.
    #: Fleet rounds and the device query path gather these instead of
    #: recomputing O(N*L) row reductions per query; None when the distance
    #: has no envelope bound.
    envelopes: Optional[bounds.EnvelopeSet] = None

    @property
    def eval_width(self) -> int:
        return self.members.shape[1]

    def append(self, pivot_rows: Sequence[int], member_ids: Sequence[int],
               member_dists: Sequence[float], new_data: Optional[np.ndarray]
               = None) -> "FlatNet":
        """Incrementally attach members (``member_ids[k]`` under pivot row
        ``pivot_rows[k]`` at distance ``member_dists[k]``) in place.

        ``new_data`` extends the window database when the ids are fresh
        (online inserts after flattening); member lists re-pad to the new
        width and pivot radii grow monotonically, so a refreshed net never
        needs a full re-flatten to stay queryable on device.
        """
        if new_data is not None and len(new_data):
            new_data = np.asarray(new_data)
            self.data = np.concatenate([self.data, new_data])
            if self.envelopes is not None:  # incremental envelope refresh
                self.envelopes.extend(bounds.build_envelopes(new_data))
        pivot_rows = np.asarray(pivot_rows, np.int64)
        member_ids = np.asarray(member_ids, np.int64)
        member_dists = np.asarray(member_dists, np.float32)
        counts = (self.members >= 0).sum(axis=1)
        need = counts.copy()
        for p in pivot_rows:
            need[p] += 1
        grow = int(need.max() - self.members.shape[1])
        if grow > 0:
            P = self.members.shape[0]
            self.members = np.concatenate(
                [self.members, np.full((P, grow), -1, np.int64)], axis=1)
            self.member_dist = np.concatenate(
                [self.member_dist, np.zeros((P, grow), np.float32)], axis=1)
        for p, w, d in zip(pivot_rows, member_ids, member_dists):
            k = int(counts[p])
            self.members[p, k] = w
            self.member_dist[p, k] = d
            counts[p] += 1
            if d > self.pivot_radius[p]:
                self.pivot_radius[p] = d
        return self

    def remove(self, member_ids: Sequence[int]) -> "FlatNet":
        """Mask windows out of every member list in place — zero distance
        evaluations.

        The elastic layer calls this when rendezvous resharding moves
        windows *out* of a shard: the departed ids can never be reported as
        hits again, while pivot rows stay behind as routing-only ghosts
        (a pivot is just a stored vector, so it keeps partitioning the
        survivors even after its own window left) and ``pivot_radius``
        keeps its monotone upper-bound property untouched.  ``envelopes``
        keep their rows too: a departed id never reappears as a candidate,
        so its (stale) envelope row is simply never gathered again.
        """
        ids = np.asarray(list(member_ids), np.int64)
        if ids.size == 0:
            return self
        drop = np.isin(self.members, ids) & (self.members >= 0)
        masked = np.where(drop, -1, self.members)
        # re-compact each row (live entries left, padding right): `append`
        # writes at the first slot past the live count, so holes must not
        # hide live members behind them
        order = np.argsort(masked < 0, axis=1, kind="stable")
        self.members = np.take_along_axis(masked, order, axis=1)
        self.member_dist = np.take_along_axis(self.member_dist, order, axis=1)
        return self


def flatten_net(net: ReferenceNet, pivot_level: Optional[int] = None
                ) -> FlatNet:
    """Flatten a host reference net at ``pivot_level`` (default ~sqrt(N)).

    Pivot->member distances come from the net itself where a member is a
    direct child of its pivot (the exact link distance is already stored —
    a bulk- or sequentially-built net hands those over for free); only the
    remaining pairs are evaluated, in a single stacked dispatch through the
    net's counter (``build`` bucket, so the flatten cost is measured on
    whichever backend the counter runs).
    """
    N = len(net.data)
    levels = sorted({n.level for n in net.nodes.values() if n.level >= 0})
    if pivot_level is None:
        # lowest level whose reference count is <= sqrt-ish of N
        target = max(1, int(math.sqrt(N)))
        pivot_level = levels[-1]
        for l in levels:
            cnt = sum(1 for n in net.nodes.values() if n.level >= l)
            if cnt <= 4 * target:
                pivot_level = l
                break
    pivot_ids = [n.idx for n in net.nodes.values() if n.level >= pivot_level]
    pivot_of = {}

    def assign(pid):
        for x in net._subtree(pid, include_self=True):
            node = net.nodes.get(x)
            if x not in pivot_of and (node is None or
                                      node.level < pivot_level or x == pid):
                pivot_of[x] = pid

    for pid in pivot_ids:
        assign(pid)
    members: List[List[int]] = [[] for _ in pivot_ids]
    pidx = {p: i for i, p in enumerate(pivot_ids)}
    for x, p in pivot_of.items():
        members[pidx[p]].append(x)
    M = max(len(m) for m in members)
    P = len(pivot_ids)
    mem = np.full((P, M), -1, np.int64)
    mdist = np.zeros((P, M), np.float32)
    # reuse stored link distances for direct children; stack the rest into
    # one batched dispatch (no per-pivot host loop)
    eval_l: List[int] = []
    eval_r: List[int] = []
    eval_at: List[Tuple[int, int]] = []
    for i, (pid, ms) in enumerate(zip(pivot_ids, members)):
        mem[i, :len(ms)] = ms
        pn = net.nodes[pid]
        link = {c: pn.child_dist[k] for k, c in enumerate(pn.children)}
        for j, x in enumerate(ms):
            if x == pid:
                mdist[i, j] = 0.0
            elif x in link:
                mdist[i, j] = link[x]
            else:
                eval_l.append(pid)
                eval_r.append(x)
                eval_at.append((i, j))
    if eval_l:
        ds = net.counter.eval_pairs(eval_l, eval_r)
        for (i, j), d in zip(eval_at, ds):
            mdist[i, j] = float(d)
    valid = mem >= 0
    radius = np.where(valid.any(axis=1),
                      np.where(valid, mdist, 0.0).max(axis=1),
                      0.0).astype(np.float32)
    # one stacked envelope pass over the whole window database (reused by
    # fleet rounds and the device query path instead of per-query rebuilds)
    envs = bounds.build_envelopes(net.data) \
        if net.dist.envelope_bound is not None else None
    return FlatNet(
        pivots=np.asarray(net.data[pivot_ids]),
        pivot_radius=radius,
        members=mem, member_dist=mdist,
        data=np.asarray(net.data), n_pivots=P, dist_name=net.dist.name,
        pivot_ids=np.asarray(pivot_ids, np.int64),
        envelopes=envs)


def _batch_dist(dist_name: str, qs, xs, interpret=True):
    """Deprecated since v0.1, removed in v0.2: batched distance lives in
    the kernel registry — call
    ``repro.kernels.registry.get(name).device_call(qs, xs)`` (or, from the
    facade, serve through ``repro.retrieval.Retriever``, which never needs
    a raw batched distance).  The device query path composes
    :meth:`KernelSpec.device_call` directly; this wrapper keeps external
    callers working for one release (the warning is suppressed inside
    facade-internal construction, mirroring the legacy-constructor
    shims)."""
    _deprecation.warn_moved("core.distributed._batch_dist",
                            "repro.kernels.registry.get(name).device_call")
    return kernel_registry.get(dist_name).device_call(
        qs, xs, interpret=interpret).dist


def device_range_query(flat: FlatNet, qs: np.ndarray, eps: float, *,
                       capacity: Optional[int] = None, interpret: bool = True,
                       q_lens: Optional[np.ndarray] = None,
                       lb_cascade="off") -> Tuple[np.ndarray, dict]:
    """Batched exact range query on one shard.

    Returns (hits (Q, N) bool, stats).  ``capacity`` is the static budget of
    survivor evaluations; on overflow the query is retried with 2x budget
    (each retry is one recompile — production sets it from telemetry).
    ``q_lens`` gives per-query actual lengths (ragged batches padded to a
    common width — the fleet layer packs every length bucket into one call).

    ``lb_cascade="envelope"`` adds an envelope-bound stage between the ring
    compaction and the exact kernel call, gathering the PRECOMPUTED
    per-window envelopes stored on the FlatNet (``flat.envelopes``): rows
    whose bound already certifies ``> eps`` are compacted away before the
    wavefront runs, and ``member_evals`` counts only the rows that reached
    it (``lb_rows`` / ``lb_pruned`` report the stage itself).  Off by
    default — counts are then bit-identical to the pre-cascade path.
    """
    Q = qs.shape[0]
    N = len(flat.data)
    if capacity is None:
        capacity = max(64, N // 4) * Q
    if q_lens is None:
        q_lens = np.full(Q, qs.shape[1], np.int32)
    mem_valid = flat.members >= 0                     # (P, M)
    mem_safe = np.maximum(flat.members, 0)
    tier = bounds.normalize_tier(lb_cascade)
    use_env = tier == "envelope" and flat.envelopes is not None
    if use_env:
        env_lo = jnp.asarray(flat.envelopes.lo)
        env_hi = jnp.asarray(flat.envelopes.hi)
        env_mass = jnp.asarray(flat.envelopes.mass)
    else:  # dummies keep operand shapes rank-stable under the static flag
        d = flat.data.shape[2] if flat.data.ndim == 3 else 1
        env_lo = jnp.zeros((1, d), jnp.float32)
        env_hi = jnp.zeros((1, d), jnp.float32)
        env_mass = jnp.zeros((1,), jnp.float32)

    def run(cap: int):
        return _device_query_jit(
            jnp.asarray(qs), jnp.asarray(q_lens, jnp.int32),
            jnp.asarray(flat.pivots),
            jnp.asarray(flat.pivot_radius), jnp.asarray(mem_safe),
            jnp.asarray(mem_valid), jnp.asarray(flat.member_dist),
            jnp.asarray(flat.data), env_lo, env_hi, env_mass,
            float(eps), cap, flat.dist_name, interpret, use_env)

    cap = int(capacity)
    while True:
        # lint: allow[trace-static-rebound] -- capacity-doubling retry: the rare overflow path recompiles by design (one trace per power of two)
        hits, n_need, n_evals, n_pruned, lb_rows, lb_pruned = run(cap)
        if int(n_need) <= cap:
            break
        cap *= 2
    stats = {"pivot_evals": Q * flat.n_pivots,
             "member_evals": int(n_evals),
             "fused_pruned": int(n_pruned),
             "lb_rows": int(lb_rows),
             "lb_pruned": int(lb_pruned),
             "capacity": cap,
             "total_evals": Q * flat.n_pivots + int(n_evals)}
    return np.asarray(hits), stats


from functools import partial


@partial(jax.jit, static_argnums=(11, 12, 13, 14, 15))
def _device_query_jit(qs, q_lens, pivots, pradius, members, mem_valid,
                      mem_dist, data, env_lo, env_hi, env_mass,
                      eps, capacity, dist_name, interpret, use_env):
    Q = qs.shape[0]
    P, M = members.shape
    N = data.shape[0]
    spec = kernel_registry.get(dist_name)
    # 1. queries x pivots — value-consuming (feeds the ring bounds)
    qs_rep = jnp.repeat(qs, P, axis=0)
    pv_rep = jnp.tile(pivots, (Q,) + (1,) * (pivots.ndim - 1))
    dp = spec.device_call(qs_rep, pv_rep, lx=jnp.repeat(q_lens, P),
                          interpret=interpret).dist.reshape(Q, P)
    # 2. pivot verdicts
    acc_all = dp + pradius[None, :] <= eps            # accept whole list
    prune_all = dp - pradius[None, :] > eps
    undecided = ~(acc_all | prune_all)
    # 3. member ring bounds for undecided pivots
    lo = jnp.abs(dp[:, :, None] - mem_dist[None, :, :])   # (Q, P, M)
    hi = dp[:, :, None] + mem_dist[None, :, :]
    member_live = mem_valid[None, :, :] & undecided[:, :, None]
    accept_m = member_live & (hi <= eps)
    need_eval = member_live & (lo <= eps) & (hi > eps)
    # scatter free verdicts into the (Q, N) hit mask
    hits = jnp.zeros((Q, N), bool)
    qq = jnp.broadcast_to(jnp.arange(Q)[:, None, None], (Q, P, M)).reshape(-1)
    ww = jnp.broadcast_to(members[None], (Q, P, M)).reshape(-1)
    free_in = ((acc_all[:, :, None] & mem_valid[None]) | accept_m).reshape(-1)
    hits = hits.at[qq, ww].max(free_in)
    # 4. compact survivors and evaluate — fused ε: the kernel returns the
    # hit mask directly and never materializes distances of pruned rows
    flat_need = need_eval.reshape(-1)
    n_need = jnp.sum(flat_need)
    sel = jnp.nonzero(flat_need, size=capacity, fill_value=0)[0]
    # jnp.nonzero pads with index 0; when flat_need[0] is genuinely true the
    # padding aliases a real survivor, so validity must be positional (the
    # first n_need rows are real), never looked up by value
    valid_sel = jnp.arange(capacity) < n_need
    q_of = sel // (P * M)
    pm = sel % (P * M)
    w_of = members.reshape(-1)[pm]
    lb_rows = jnp.zeros((), jnp.int32)
    lb_pruned = jnp.zeros((), jnp.int32)
    if use_env:
        # 4b. envelope stage on the compacted survivors: gather the
        # PRECOMPUTED per-window boxes/masses (built once at flatten time)
        # and compact a second time, so only rows the envelope bound cannot
        # certify as > eps reach the exact wavefront.  One-direction form of
        # the sound bounds in ``distances/bounds.py::lb_envelope_rows``.
        xq = qs[q_of]
        if xq.ndim == 2:
            xq = xq[..., None]
        Lq = xq.shape[1]
        mx = jnp.arange(Lq)[None, :] < q_lens[q_of][:, None]    # (C, L)
        lo_r = env_lo[w_of][:, None, :]                         # (C, 1, d)
        hi_r = env_hi[w_of][:, None, :]
        gap = jnp.maximum(lo_r - xq, 0.0) + jnp.maximum(xq - hi_r, 0.0)
        bd = jnp.sqrt(jnp.maximum(jnp.sum(gap * gap, -1), 0.0))  # (C, L)
        if dist_name == "frechet":
            lb = jnp.max(jnp.where(mx, bd, 0.0), axis=1)
        elif dist_name == "dtw":
            lb = jnp.sum(jnp.where(mx, bd, 0.0), axis=1)
        else:  # erp: element consumption + global gap-mass bound
            gx = jnp.where(mx, jnp.sqrt(
                jnp.maximum(jnp.sum(xq * xq, -1), 0.0)), 0.0)
            cons = jnp.sum(jnp.where(mx, jnp.minimum(gx, bd), 0.0), axis=1)
            gm = jnp.abs(gx.sum(axis=1) - env_mass[w_of])
            lb = jnp.maximum(cons, gm)
        keep = valid_sel & (lb <= eps)
        lb_rows = jnp.sum(valid_sel)
        lb_pruned = jnp.sum(valid_sel & ~keep)
        n_keep = jnp.sum(keep)
        sel2 = jnp.nonzero(keep, size=capacity, fill_value=0)[0]
        valid_sel = jnp.arange(capacity) < n_keep
        q_of, w_of = q_of[sel2], w_of[sel2]
    out = spec.device_call(qs[q_of], data[w_of], lx=q_lens[q_of], eps=eps,
                           interpret=interpret)
    good = valid_sel & out.hit
    hits = hits.at[q_of, w_of].max(good)
    return (hits, n_need, jnp.sum(valid_sel),
            jnp.sum(valid_sel & out.pruned), lb_rows, lb_pruned)


def host_reference_hits(flat: FlatNet, qs: np.ndarray, eps: float
                        ) -> np.ndarray:
    """Oracle: exact (Q, N) hit mask by brute force (numpy backend)."""
    batch = np_backend.batch_for(flat.dist_name)
    Q, N = qs.shape[0], len(flat.data)
    # ONE stacked oracle call over the full (Q, N) cross product
    ds = np.asarray(batch(
        np.repeat(qs, N, axis=0),
        np.tile(flat.data, (Q,) + (1,) * (flat.data.ndim - 1))))
    return ds.reshape(Q, N) <= eps


# -- fleet (multi-shard) version ---------------------------------------------

def merge_flats(flats: Sequence[FlatNet]) -> Tuple[FlatNet, List[int]]:
    """Stack per-shard FlatNets into ONE flat net over the union.

    Shards partition the windows, so concatenating pivot rows (member ids
    offset into the concatenated data array, member widths padded to the
    fleet maximum) yields a FlatNet whose single device query equals the
    union of the per-shard queries.  Pivot identities survive the merge —
    ``pivot_ids`` concatenate with the same per-shard offsets, so post-merge
    :meth:`FlatNet.append` refreshes keep working — when every input carries
    them (otherwise the merged net's are None).  Returns the merged net plus
    each shard's column offset into the merged hit mask.
    """
    assert flats, "nothing to merge"
    assert len({f.dist_name for f in flats}) == 1, "mixed distances"
    M = max(f.members.shape[1] for f in flats)
    offsets: List[int] = []
    mems, mdists, off = [], [], 0
    for f in flats:
        offsets.append(off)
        pad = M - f.members.shape[1]
        mem = np.pad(f.members, ((0, 0), (0, pad)), constant_values=-1)
        mems.append(np.where(mem >= 0, mem + off, -1))
        mdists.append(np.pad(f.member_dist, ((0, 0), (0, pad))))
        off += len(f.data)
    pivot_ids = None
    if all(f.pivot_ids is not None for f in flats):
        pivot_ids = np.concatenate(
            [np.asarray(f.pivot_ids, np.int64) + o
             for f, o in zip(flats, offsets)])
    envs = None
    if all(f.envelopes is not None for f in flats):
        e0 = flats[0].envelopes
        envs = bounds.EnvelopeSet(e0.lo.copy(), e0.hi.copy(),
                                  e0.mass.copy(), e0.cum.copy(),
                                  e0.lens.copy())
        for f in flats[1:]:
            envs.extend(f.envelopes)
    return FlatNet(
        pivots=np.concatenate([f.pivots for f in flats]),
        pivot_radius=np.concatenate([f.pivot_radius for f in flats]),
        members=np.concatenate(mems),
        member_dist=np.concatenate(mdists),
        data=np.concatenate([f.data for f in flats]),
        n_pivots=sum(f.n_pivots for f in flats),
        dist_name=flats[0].dist_name, pivot_ids=pivot_ids,
        envelopes=envs), offsets


def fleet_range_query(flats: List[FlatNet], qs: np.ndarray, eps: float,
                      *, dead: Tuple[int, ...] = (), stacked: bool = True,
                      merged: Optional[Tuple[FlatNet, List[int]]] = None,
                      **kw):
    """Union of per-shard device queries (shards partition the windows).

    This is the fleet's *one-shot* serving primitive — since PR 6 the
    elastic layer's fallback mode (``mode="oneshot"``); default serving
    goes round-based through ``FleetBatchEngine`` instead, which prunes
    with the full reference-net frontier (see ``launch/elastic.py``).

    ``dead`` shards are skipped (the elastic layer rebuilds them); the
    returned mask is per-shard so the caller can re-issue stolen work.

    ``stacked`` (default) merges the alive shards' FlatNet arrays with
    :func:`merge_flats` and runs ONE device query over the stack — one
    pivot-kernel call and one survivor compaction for the whole fleet
    instead of a sequential host-Python loop over shards.  Results are
    identical; per-shard masks are column slices of the merged mask.  A
    merged run cannot attribute evaluations to individual shards, so each
    alive shard's stats entry is an independent dict tagged
    ``merged=True`` whose counters use ``fleet_*`` keys (summing them
    across shards would double-count — old per-shard keys are absent on
    purpose).  ``stacked=False`` keeps the per-shard loop with the
    classic per-shard stats (useful when shards genuinely live on
    different processes).

    ``merged`` lets a serving layer pass a precomputed
    ``merge_flats``-of-the-alive-shards result (net, offsets) so repeated
    queries against an unchanged fleet skip the per-call merge; it MUST
    correspond to the current alive list or the column slicing is wrong.
    """
    alive = [(i, f) for i, f in enumerate(flats) if i not in dead]
    results: List[Optional[np.ndarray]] = [None] * len(flats)
    stats: List[Optional[dict]] = [None] * len(flats)
    if stacked and len(alive) > 1:
        if merged is not None:
            mnet, offsets = merged
        else:
            mnet, offsets = merge_flats([f for _, f in alive])
        hits, s = device_range_query(mnet, qs, eps, **kw)
        fleet = {"merged": True, "n_shards": len(alive),
                 "capacity": s["capacity"],
                 "fleet_pivot_evals": s["pivot_evals"],
                 "fleet_member_evals": s["member_evals"],
                 "fleet_fused_pruned": s.get("fused_pruned", 0),
                 "fleet_lb_rows": s.get("lb_rows", 0),
                 "fleet_lb_pruned": s.get("lb_pruned", 0),
                 "fleet_total_evals": s["total_evals"]}
        for (i, f), off in zip(alive, offsets):
            results[i] = hits[:, off:off + len(f.data)]
            stats[i] = dict(fleet)
        return results, stats
    for i, f in alive:
        h, st = device_range_query(f, qs, eps, **kw)
        results[i] = h
        stats[i] = st
    return results, stats
