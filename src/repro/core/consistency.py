"""The consistency property (paper Def. 1) — machine-checkable form.

``delta`` is *consistent* iff for all sequences Q, X and every contiguous
subsequence SX of X there exists a contiguous subsequence SQ of Q with
``delta(SQ, SX) <= delta(Q, X)``.

The paper proves consistency analytically for Euclidean, Hamming, DTW, ERP,
DFD and Levenshtein (§4).  This module provides the brute-force verifier the
property tests use to re-derive that claim empirically, plus helpers shared
with the segmentation lemmas.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.distances.base import Distance


def all_subsequences(n: int, min_len: int = 1) -> List[Tuple[int, int]]:
    """All (start, length) pairs of contiguous subsequences of a length-n seq."""
    return [(a, ln) for ln in range(min_len, n + 1) for a in range(n - ln + 1)]


def _pad_stack(seqs, L, string):
    if string:
        out = np.zeros((len(seqs), L), np.int32)
    else:
        d = seqs[0].shape[-1] if seqs[0].ndim == 2 else 1
        out = np.zeros((len(seqs), L, d), np.float32)
    lens = np.zeros((len(seqs),), np.int32)
    for i, s in enumerate(seqs):
        s = np.asarray(s)
        if not string and s.ndim == 1:
            s = s[:, None]
        out[i, : len(s)] = s
        lens[i] = len(s)
    return out, lens


def check_consistency(dist: Distance, Q, X, atol: float = 1e-4) -> bool:
    """Brute-force Def. 1 check: every SX has an SQ with d(SQ,SX) <= d(Q,X).

    Exponential in nothing but quadratic in |Q|,|X| pairs of subsequences; use
    short sequences (<= ~10) in tests.
    """
    Q, X = np.asarray(Q), np.asarray(X)
    dQX = float(dist.pair(_fix(Q, dist), _fix(X, dist)))
    L = max(len(Q), len(X))
    sx = [(X[a : a + ln]) for a, ln in all_subsequences(len(X))]
    sq = [(Q[a : a + ln]) for a, ln in all_subsequences(len(Q))]
    if dist.variable_length is False:
        # Equal-length distances: SQ must have the same length as SX.
        for xs_sub in sx:
            cand = [q for q in sq if len(q) == len(xs_sub)]
            best = min(float(dist.pair(_fix(q, dist), _fix(xs_sub, dist))) for q in cand)
            if best > dQX + atol:
                return False
        return True
    xs_pad, xs_len = _pad_stack(sx, L, dist.string)
    qs_pad, qs_len = _pad_stack(sq, L, dist.string)
    mat = np.asarray(dist.matrix(qs_pad, xs_pad, qs_len, xs_len))
    best_per_sx = mat.min(axis=0)
    return bool(np.all(best_per_sx <= dQX + atol))


def _fix(s, dist):
    s = np.asarray(s)
    if not dist.string and s.ndim == 1:
        s = s[:, None]
    return s
