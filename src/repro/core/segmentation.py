"""Segmentation (paper §5, §7 steps 1 & 3).

* Database sequences are partitioned into fixed-length, non-overlapping
  windows of length ``l = lambda/2`` (Lemma 2: l <= lambda/2 guarantees every
  similar subsequence of length >= lambda fully contains a window).
* Query sequences yield *sliding* segments of every length in
  ``[l - lambda0, l + lambda0]`` (at most (2*lambda0+1)*|Q| segments).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Window:
    """A database window: sequence ``seq_id``, elements [start, start+length)."""
    seq_id: int
    start: int
    length: int


@dataclasses.dataclass(frozen=True)
class Segment:
    """A query segment: elements [start, start+length) of the query."""
    start: int
    length: int


def window_length(lam: int) -> int:
    """Lemma 2: the fixed window length is lambda // 2 (l <= lambda/2)."""
    if lam < 2:
        raise ValueError("lambda must be >= 2")
    return lam // 2


def partition_windows(seqs: Sequence[np.ndarray], lam: int
                      ) -> Tuple[np.ndarray, List[Window]]:
    """Partition database sequences into fixed windows of length lambda//2.

    Returns (stacked window array (n_win, l[, d]), window metadata).
    Trailing remainders shorter than l are dropped, as in the paper
    (|X|/l windows per sequence).
    """
    l = window_length(lam)
    arrays, meta = [], []
    for sid, x in enumerate(seqs):
        x = np.asarray(x)
        n = len(x) // l
        for w in range(n):
            arrays.append(x[w * l:(w + 1) * l])
            meta.append(Window(seq_id=sid, start=w * l, length=l))
    if not arrays:
        raise ValueError("no windows produced; sequences shorter than lambda/2")
    return np.stack(arrays), meta


def query_segments(Q: np.ndarray, lam: int, lambda0: int
                   ) -> Dict[int, Tuple[np.ndarray, List[Segment]]]:
    """Extract all query segments with lengths in [l-lambda0, l+lambda0].

    Returns {length: (stacked (n, length[, d]) array, segment metadata)} —
    bucketed by length so the batched distance kernels see static shapes.
    """
    Q = np.asarray(Q)
    l = window_length(lam)
    out: Dict[int, Tuple[np.ndarray, List[Segment]]] = {}
    lmin = max(1, l - lambda0)
    lmax = l + lambda0
    for ln in range(lmin, lmax + 1):
        if ln > len(Q):
            continue
        segs = [Segment(start=a, length=ln) for a in range(len(Q) - ln + 1)]
        arr = np.stack([Q[s.start:s.start + ln] for s in segs])
        out[ln] = (arr, segs)
    return out


def subsequence(x: np.ndarray, start: int, length: int) -> np.ndarray:
    return np.asarray(x)[start:start + length]
