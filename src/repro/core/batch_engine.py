"""Batched frontier-expansion engine for the step-4 hot path.

The paper's range queries (Algorithm 3 and both baselines) are naturally
round-structured: each round the index knows a *frontier* — the set of
still-undecided candidates whose distances it needs next — and nothing
about round k+1 depends on anything but the distances returned for round k.
Pair-at-a-time host traversal throws that structure away; this module keeps
it.

Indexes describe a range query as a **plan**: a generator that

* yields :class:`Frontier` batches of candidate window indices,
* receives the corresponding ``(m,)`` float32 distances back via ``send``,
* and returns the sorted hit list via ``StopIteration.value``.

Two drivers consume plans:

* :func:`drive` — sequential host mode, one dispatch per frontier.  Used by
  every index's classic ``range_query``; evaluation order and counts are
  bit-identical to the historical pair/level-at-a-time path.
* :class:`BatchEngine` — runs *many* concurrent plans (ALL query segments
  across every length bucket, §5: there are only ``2*lambda0 + 1`` of
  them) in lockstep rounds, folding every plan's current frontier into
  **one** ``Distance.batch`` dispatch per round.  Rows carry their own
  lengths, so the packed ragged-bucket kernel dispatcher
  (``kernels/dispatch.py``) serves a whole round in one device call —
  ``CountedDistance(backend="pallas")`` included, with fused ε-pruning for
  verdict-only rows.

Frontiers carry a ``kind``:

* ``EXACT``   — the plan consumes the distance *value* (e.g. a reference
  whose distance feeds Lemma-4 bound propagation); always evaluated.
* ``VERDICT`` — the plan only consumes the ``<= eps`` verdict (leaf
  membership checks, linear-scan rows, MV survivors).  With the LB cascade
  enabled, a cheap provable lower bound (``distances/bounds.py``) runs
  first and candidates with ``lb > eps`` skip the exact O(l^2) DP entirely;
  the bound value is returned in place of the distance, which preserves the
  verdict because ``lb <= delta``.  With the cascade off (default), engine
  results — hit sets AND exact-evaluation counts — are identical to host
  mode.

Frontiers also carry an accounting ``bucket`` (``counter.QUERY`` /
``counter.BUILD``): construction plans (``ReferenceNet.insert_plan``) charge
the counter's build bucket so query-time pruning ratios stay clean.

Plans are not restricted to query-vs-window work.  ``BatchEngine.run``
accepts either a ``(n_plans, l[, d])`` array of query rows *or* a 1-D
integer vector of **data indices** — the pairwise (node-vs-node) mode used
by bulk construction, where plan ``i``'s left-hand side is
``counter.data[queries[i]]``.  Everything else (round merging, one dispatch
per round, per-plan send) is identical.

A third driver, :class:`FleetBatchEngine`, extends the round merge *across
shards*: every alive shard of the elastic fleet contributes its own plans
(over its own shard-local database), and each merged round is still ONE
evaluator call — the round-based fleet serving path (`launch/elastic.py`,
``mode="rounds"``) that keeps the frontier's pruning while paying device
dispatches per round, not per shard per query per round.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Generator, List, Optional, Sequence, Set

import numpy as np

from repro.core.counter import BUILD, QUERY, CountedDistance
from repro.distances import bounds

EXACT = "exact"
VERDICT = "verdict"

#: yields Frontier, receives (m,) float32 distances, returns the plan's
#: result (sorted hit list for queries, an InsertOutcome for construction)
Plan = Generator


@dataclasses.dataclass
class Frontier:
    """One round of undecided candidates of a single frontier plan."""
    idxs: np.ndarray
    kind: str = EXACT
    bucket: str = QUERY     # counter accounting bucket (QUERY / BUILD)

    def __post_init__(self):
        self.idxs = np.asarray(self.idxs, np.int64)


def drive(plan: Plan, counter: CountedDistance, q: np.ndarray,
          q_len: Optional[int] = None, *, eps: Optional[float] = None,
          lb_cascade=False):
    """Sequential host-mode driver: one backend dispatch per frontier.

    ``lb_cascade`` is a tier (``"off" | "endpoint" | "envelope"``; legacy
    booleans map to off/endpoint).  With a tier active, VERDICT frontiers
    route through the counter's staged cascade — pruned candidates answer
    with their (verdict-preserving) lower bound and skip the exact DP.
    """
    tier = bounds.normalize_tier(lb_cascade)
    q = np.asarray(q)
    qlen = len(q) if q_len is None else int(q_len)
    try:
        fr = next(plan)
        while True:
            idxs = fr.idxs
            if tier != "off" and eps is not None and fr.kind == VERDICT:
                qs = np.repeat(q[None, :qlen], idxs.size, 0)
                ds = counter.eval_stacked(qs, idxs, qlen, bucket=fr.bucket,
                                          eps=eps, lb_tier=tier)
            else:
                ds = counter.eval(q, idxs, qlen, bucket=fr.bucket)
            fr = plan.send(ds)
    except StopIteration as stop:
        return stop.value if stop.value is not None else []


class BatchEngine:
    """Run many concurrent range-query plans, one dispatch per round.

    Plans of EVERY length bucket run together (pass a list of ragged query
    rows): each merged round is a single packed ``Distance.batch`` dispatch
    regardless of how many segments, buckets, levels, or candidate lists
    contributed to it — per-row lengths ride through the counter into the
    packed kernel dispatcher.  Uniform-length calls behave exactly as the
    historical per-bucket engine (same counts, same dispatch sequence).
    """

    def __init__(self, counter: CountedDistance, *, lb_cascade=False):
        self.counter = counter
        #: cascade tier ("off" | "endpoint" | "envelope"); legacy booleans
        #: normalize to off/endpoint
        self.lb_cascade = bounds.normalize_tier(lb_cascade)
        self.rounds = 0  # merged frontier rounds (diagnostics / benchmarks)

    def run(self, plans: Sequence[Plan], queries, eps: float,
            q_len: Optional[int] = None) -> List[List[int]]:
        """Drive ``plans[i]`` with query row ``queries[i]``; returns each
        plan's result.  Hit sets and exact-eval counts match sequential host
        mode.

        ``queries`` may instead be a 1-D integer vector of indices into
        ``counter.data`` — the pairwise (node-vs-node) mode: plan ``i``'s
        left-hand rows are gathered from the indexed database itself, which
        is how bulk construction drives cohorts of concurrent insert plans.

        ``queries`` may also be a *list* of rows with differing lengths —
        the packed ragged-bucket mode: plans from every length bucket run
        in lockstep, and each merged round is still ONE backend dispatch
        (rows carry their own lengths through the packed dispatcher), so
        dispatches scale with rounds, not rounds x buckets.
        """
        qlens: Optional[np.ndarray] = None  # per-plan lengths (packed mode)
        if not isinstance(queries, np.ndarray) and q_len is None:
            from repro.kernels.dispatch import pad_ragged_rows
            rows = [np.asarray(q) for q in queries]
            if len({len(r) for r in rows}) > 1:
                queries, qlens = pad_ragged_rows(rows)
            else:
                queries = np.stack(rows) if rows \
                    else np.zeros((0, 0), np.float32)
        queries = np.asarray(queries)
        pair_mode = queries.ndim == 1 and queries.dtype.kind in "iu"
        assert len(plans) == len(queries), "one query row per plan"
        if qlens is not None:
            qlen = None
        elif q_len is not None:
            qlen = int(q_len)
        elif pair_mode:
            qlen = self.counter.data.shape[1]
        else:
            qlen = queries.shape[1]

        def qrows(row_ids: np.ndarray) -> np.ndarray:
            rows = self.counter.data[queries[row_ids]] if pair_mode \
                else queries[row_ids]
            return rows if qlen is None else rows[:, :qlen]

        def row_lens(row_ids: np.ndarray):
            return qlen if qlens is None else qlens[row_ids]

        results: List[Optional[List[int]]] = [None] * len(plans)

        state = {}
        for i, p in enumerate(plans):
            try:
                state[i] = next(p)
            except StopIteration as stop:
                results[i] = stop.value if stop.value is not None else []

        while state:
            order = sorted(state)
            sizes = [state[i].idxs.size for i in order]
            cand = np.concatenate([state[i].idxs for i in order]) \
                if sizes else np.zeros((0,), np.int64)
            rows = np.concatenate(
                [np.full(m, i, np.int64) for i, m in zip(order, sizes)]) \
                if sizes else np.zeros((0,), np.int64)
            verdict = np.concatenate(
                [np.full(m, state[i].kind == VERDICT)
                 for i, m in zip(order, sizes)]) \
                if sizes else np.zeros((0,), bool)
            # a merged round is charged to BUILD only when every contributing
            # frontier is construction work (one call site never mixes them)
            bucket = BUILD if all(state[i].bucket == BUILD for i in order) \
                else QUERY

            tier = bounds.normalize_tier(self.lb_cascade)
            if tier != "off" and verdict.any():
                # staged cascade INSIDE the round: per-row ε carries the
                # query ε on verdict rows and +inf on value-consuming EXACT
                # rows (they opt out of every bound and of fused masking);
                # the counter runs tier-0 / envelope bounds and compacts
                # only the survivors into the single exact dispatch.
                feps = np.where(verdict, np.float32(eps),
                                np.float32(np.inf))
                ds = self.counter.eval_stacked(
                    qrows(rows), cand, row_lens(rows),
                    bucket=bucket, eps=feps, lb_tier=tier)
            elif cand.size:
                # the ONE exact dispatch of this round — every plan, every
                # length bucket.  On a fused backend, verdict-only rows
                # carry the query ε (their values come back verdict-masked),
                # value-consuming EXACT rows opt out via +inf.
                feps = None
                if self.counter.fused:
                    feps = np.where(verdict, np.float32(eps),
                                    np.float32(np.inf))
                ds = self.counter.eval_stacked(
                    qrows(rows), cand, row_lens(rows),
                    bucket=bucket, eps=feps)
            else:
                ds = np.zeros(0, np.float32)
            self.rounds += 1

            new_state = {}
            off = 0
            for i, m in zip(order, sizes):
                try:
                    new_state[i] = plans[i].send(ds[off:off + m])
                except StopIteration as stop:
                    results[i] = stop.value if stop.value is not None else []
                off += m
            state = new_state
        return results  # type: ignore[return-value]


@dataclasses.dataclass
class ShardPlans:
    """One shard's contribution to a cross-shard frontier run.

    ``plans[i]`` is a range-query plan over this shard's *local* database
    (frontier idxs index ``data``); ``queries`` holds one padded query row
    per plan with ``q_lens`` giving the actual lengths (ragged batches share
    one padded width across the whole fleet).  ``shard`` is the provenance
    id (the fleet worker slot) that rides every evaluated row into the
    packed dispatcher's per-shard accounting.  ``lb`` optionally overrides
    the engine-wide envelope hook for this group's rows — the serve layer
    uses it so requests admitted before and after a fleet swap each screen
    against the envelopes of the fleet that admitted them."""
    shard: int
    data: np.ndarray                # (rows, l[, d]) shard-local windows
    plans: Sequence[Plan]
    queries: np.ndarray             # (n_plans, W[, d]) padded query rows
    q_lens: np.ndarray              # (n_plans,) actual query lengths
    lb: Optional[object] = None     # per-group envelope hook (else engine's)


@dataclasses.dataclass
class _Admitted:
    """One admitted batch of a cross-shard run: its groups, its ε, and the
    per-group/per-plan result slots still being filled."""
    groups: List[ShardPlans]
    eps: float
    results: List[List[Optional[List[int]]]]
    live: int = 0                   # plans not yet run to StopIteration


class FleetBatchEngine:
    """Cross-shard frontier merge: one evaluator call per merged round.

    :class:`BatchEngine` merges concurrent plans over ONE database;
    this engine merges plans over MANY shard-local databases — the
    round-based fleet serving path.  Each round it concatenates every
    alive plan's frontier (survivors only — plans that finished, and dead
    workers' plans that were never admitted, simply contribute no rows),
    gathers candidate windows from each plan's own shard, and issues ONE
    ``evaluate`` call spanning all shards and all length buckets.  On a
    fused backend, VERDICT rows carry their batch's ε (pruned candidates
    never have distances materialized — the kernel returns verdict-masked
    sentinels), EXACT rows opt out via ``+inf``, exactly as in
    :class:`BatchEngine`.

    The engine is **incremental**: :meth:`admit` joins a batch of plans
    (its own ε, its own shard groups) to the shared cadence at the next
    round boundary, :meth:`step` advances every in-flight plan by ONE
    merged round, and finished batches retire their rows immediately —
    this is the substrate of the continuous-batching serve layer
    (``repro/serve/engine.py``), where requests from different callers
    arrive asynchronously and still share packed dispatches.
    :meth:`run` (admit once, step until drained) preserves the historical
    one-shot contract bit for bit: with a single admitted batch the merged
    row order, frontier sequence, and evaluation counts are identical.

    Evaluation accounting is the caller's: the engine tallies
    ``exact_evals`` / ``verdict_evals`` (requested rows only — backend
    padding never reaches it), per-shard row provenance in ``shard_rows``,
    and the fused-prune certificate count, and the elastic layer folds
    those into ``ElasticIndex.device_stats`` — never into the shards' host
    counters, so the ``{query, build}`` buckets stay host-path currency.
    Frontier sequences are identical to driving each plan sequentially, so
    total evaluations match the host per-shard loop row for row.
    """

    def __init__(self, evaluate, *, fused: bool = False, lb=None):
        #: ``evaluate(xs, ys, lx, ly, eps_rows, shard_ids) -> (dists,
        #: n_pruned)`` — one backend call per merged round
        self.evaluate = evaluate
        self.fused = fused
        #: optional envelope-cascade hook ``lb(shard, idxs, q, q_len) ->
        #: (m,) bounds`` over a shard's PRECOMPUTED per-window envelopes
        #: (``FlatNet.envelopes``).  VERDICT rows with ``lb > eps`` answer
        #: with the bound and never enter the merged evaluate call.
        self.lb = lb
        self.rounds = 0
        self.exact_evals = 0
        self.verdict_evals = 0
        self.fused_pruned = 0
        self.lb_rows = 0
        self.lb_pruned = 0
        self.shard_rows: Dict[int, int] = {}
        self._next_bid = 0
        self._admitted: Dict[int, _Admitted] = {}
        self._state: Dict[tuple, Frontier] = {}   # (bid, g, i) -> frontier

    # -- incremental API (continuous batching) ------------------------------

    def admit(self, groups: Sequence[ShardPlans], eps: float) -> int:
        """Join a batch of plans to the shared cadence; returns its id.

        Plans are primed here (their first frontier is produced), so the
        batch's round-1 rows merge into the very next :meth:`step` — new
        requests join at the round boundary, no drain/restart."""
        bid = self._next_bid
        self._next_bid += 1
        batch = _Admitted(list(groups), float(eps),
                          [[None] * len(g.plans) for g in groups])
        self._admitted[bid] = batch
        for g, grp in enumerate(batch.groups):
            for i, p in enumerate(grp.plans):
                try:
                    self._state[(bid, g, i)] = next(p)
                    batch.live += 1
                except StopIteration as stop:
                    batch.results[g][i] = stop.value \
                        if stop.value is not None else []
        return bid

    @property
    def active(self) -> bool:
        """True while any admitted plan still has frontiers to evaluate."""
        return bool(self._state)

    def batches_in_flight(self) -> Set[int]:
        """Batch ids that would contribute rows to the next round."""
        return {k[0] for k in self._state}

    def is_finished(self, bid: int) -> bool:
        return bid in self._admitted and self._admitted[bid].live == 0

    def results(self, bid: int) -> List[List[List[int]]]:
        """Pop a finished batch's per-group, per-plan results."""
        batch = self._admitted[bid]
        if batch.live:
            raise ValueError(f"batch {bid} still has {batch.live} live plans")
        del self._admitted[bid]
        return batch.results  # type: ignore[return-value]

    def step(self, only: Optional[Set[int]] = None) -> List[int]:
        """Advance every in-flight plan (or the ``only`` batch subset) by
        ONE merged round — one evaluator call across all batches, shards,
        and length buckets.  Returns the batch ids that finished."""
        keys = [k for k in sorted(self._state)
                if only is None or k[0] in only]
        if not keys:
            return []

        def _widen(parts):
            # batches admitted at different times pad their query rows
            # independently; harmonize widths before the concat (no-op —
            # and bit-identical — when one batch is in flight, i.e. run())
            W = max(p.shape[1] for p in parts)
            return [p if p.shape[1] == W else
                    np.pad(p, ((0, 0), (0, W - p.shape[1]))
                           + ((0, 0),) * (p.ndim - 2)) for p in parts]

        sizes = [self._state[k].idxs.size for k in keys]
        xs_parts, ys_parts, lx_parts, ly_parts = [], [], [], []
        shard_parts, verdict_parts = [], []
        part_keep, part_lb = [], []  # per-part cascade masks / bounds
        eps_parts = []               # per-row ε (each batch carries its own)
        for k, m in zip(keys, sizes):
            bid, g, i = k
            batch = self._admitted[bid]
            grp = batch.groups[g]
            fr = self._state[k]
            keep = np.ones(m, bool)
            lbv = None
            hook = grp.lb if grp.lb is not None else self.lb
            if hook is not None and fr.kind == VERDICT and m:
                # envelope tier over the shard's precomputed per-window
                # envelopes: pruned rows answer with the bound below
                # and never enter the merged evaluate call
                lbv = np.asarray(
                    hook(grp.shard, fr.idxs, grp.queries[i],
                         int(grp.q_lens[i])), np.float32)
                keep = lbv <= batch.eps
                self.lb_rows += m
                self.lb_pruned += int(m - keep.sum())
            part_keep.append(keep)
            part_lb.append(lbv)
            mk = int(keep.sum())
            xs_parts.append(np.repeat(grp.queries[i][None], mk, 0))
            ys_parts.append(grp.data[fr.idxs[keep]])
            lx_parts.append(np.full(mk, int(grp.q_lens[i]), np.int64))
            ly_parts.append(np.full(mk, grp.data.shape[1], np.int64))
            shard_parts.append(np.full(mk, grp.shard, np.int64))
            verdict_parts.append(np.full(mk, fr.kind == VERDICT))
            eps_parts.append(np.full(
                mk, batch.eps if fr.kind == VERDICT else np.inf, np.float32))
            self.shard_rows[grp.shard] = \
                self.shard_rows.get(grp.shard, 0) + mk
        xs = np.concatenate(_widen(xs_parts))
        ys = np.concatenate(_widen(ys_parts))
        lx = np.concatenate(lx_parts)
        ly = np.concatenate(ly_parts)
        shard_ids = np.concatenate(shard_parts)
        verdict = np.concatenate(verdict_parts)

        if len(xs):
            eps_rows = np.concatenate(eps_parts) if self.fused else None
            ds, n_pruned = self.evaluate(xs, ys, lx, ly, eps_rows,
                                         shard_ids)
            ds = np.asarray(ds, np.float32)
        else:  # every row of the round was envelope-pruned
            ds, n_pruned = np.zeros(0, np.float32), 0
        self.rounds += 1
        self.exact_evals += int((~verdict).sum())
        self.verdict_evals += int(verdict.sum())
        self.fused_pruned += int(n_pruned)

        finished: List[int] = []
        off = 0
        for k, m, keep, lbv in zip(keys, sizes, part_keep, part_lb):
            bid, g, i = k
            batch = self._admitted[bid]
            mk = int(keep.sum())
            out = np.empty(m, np.float32)
            if lbv is not None:
                out[~keep] = lbv[~keep]
            out[keep] = ds[off:off + mk]
            try:
                self._state[k] = batch.groups[g].plans[i].send(out)
            except StopIteration as stop:
                del self._state[k]
                batch.results[g][i] = stop.value \
                    if stop.value is not None else []
                batch.live -= 1
                if batch.live == 0:
                    finished.append(bid)
            off += mk
        return finished

    # -- one-shot contract (admit once, drain) ------------------------------

    def run(self, groups: Sequence[ShardPlans], eps: float
            ) -> List[List[List[int]]]:
        """Drive every group's plans in lockstep to completion; returns
        per-group, per-plan results (shard-local hit lists, same order as
        ``plans``).  Equivalent to ``admit`` + ``step`` until drained —
        with one batch the merged rounds are identical to the historical
        one-shot engine, row for row."""
        bid = self.admit(groups, eps)
        while self._state:
            self.step()
        return self.results(bid)
