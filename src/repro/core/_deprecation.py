"""Deprecation plumbing for the legacy public entry points.

Since the ``repro.retrieval`` facade became the canonical API, direct
construction of the old entry points (``SubsequenceMatcher``,
``ElasticIndex``, ``EmbeddingRetriever``) is deprecated.  The classes are
still the implementation the facade delegates to, so the warning is only
emitted for *direct* construction — the facade wraps its internal
constructions in :func:`facade_construction`, which suppresses it.
"""

from __future__ import annotations

import contextlib
import threading
import warnings

_state = threading.local()


@contextlib.contextmanager
def facade_construction():
    """Suppress legacy-constructor warnings for facade-internal builds."""
    prev = getattr(_state, "internal", False)
    _state.internal = True
    try:
        yield
    finally:
        _state.internal = prev


def warn_legacy(entry_point: str) -> None:
    """Emit the deprecation warning unless the facade is constructing."""
    if getattr(_state, "internal", False):
        return
    warnings.warn(
        f"direct construction of {entry_point} is deprecated; build it "
        "through the facade instead: "
        "repro.retrieval.Retriever.build(RetrievalConfig(...), data)",
        DeprecationWarning, stacklevel=3)


def warn_moved(old: str, new: str) -> None:
    """Deprecation for relocated internals (e.g. ``_batch_dist`` -> the
    kernel registry).  Same suppression rule as the constructor shims, so
    facade-internal delegation stays silent while external callers get one
    release of warning."""
    if getattr(_state, "internal", False):
        return
    warnings.warn(
        f"{old} has moved to {new}; this delegation shim will be removed "
        "in the next release", DeprecationWarning, stacklevel=3)
