"""Cover-tree baseline (Beygelzimer et al., ICML'06) — paper's comparison.

Structurally a reference net restricted to a single parent per node
(``num_max = 1``, nearest covering reference), which is exactly the
net-vs-tree distinction of the paper's Fig. 2: with one parent, a query may
have to descend lists whose reference is far from Q even when another,
closer reference also covers the same data.  Implemented as a thin subclass
so both structures share traversal, counting, invariant, and construction
machinery — including the plan-based ``insert_plan``/``build_batched`` bulk
loader (cohort arbitration keeps only the nearest covering owner here, via
``num_max=1``) — space/query differences then isolate the multi-parent
effect, as in the paper's §8.2 comparison.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.counter import CountedDistance
from repro.core.refnet import ReferenceNet
from repro.distances import base as dist_base


class CoverTree(ReferenceNet):
    def __init__(self, dist: dist_base.Distance, data: np.ndarray, *,
                 eps_prime: float = 1.0, tight_bounds: bool = False,
                 counter: Optional[CountedDistance] = None):
        super().__init__(dist, data, eps_prime=eps_prime, num_max=1,
                         tight_bounds=tight_bounds, counter=counter)

    def check_invariants(self) -> None:
        super().check_invariants()
        for n in self.nodes.values():
            if n.idx != self.root:
                assert len(n.parents) == 1, "cover tree must be single-parent"
