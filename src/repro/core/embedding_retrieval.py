"""Embedding-space subsequence retrieval — the paper's framework applied to
model hidden states (the integration point between the two halves of this
system; DESIGN.md §2).

Hidden-state windows are fixed-length sequences over (R^d, L2); Euclidean is
metric AND consistent (paper §4), so the full stack applies: windows ->
reference net -> range/NN queries.  Because the windows all share one
length, the degenerate-but-legal Euclidean case of the framework applies
(paper §5 notes its alignment rigidity; for same-length embedding windows
that rigidity is exactly what's wanted).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.counter import CountedDistance
from repro.core.refnet import ReferenceNet
from repro.core.segmentation import Window
from repro.distances import get
from repro.models.layers import Ctx, NOCTX


def embed_windows(model, params, cfg, token_seqs: Sequence[np.ndarray],
                  window: int, *, ctx: Ctx = NOCTX, stride: Optional[int] = None,
                  normalize: bool = True) -> Tuple[np.ndarray, List[Window]]:
    """Run the model, mean-pool hidden states over fixed windows.

    Returns (windows (N, d) float32, metadata).  Window = contiguous span of
    ``window`` tokens; stride defaults to the window (non-overlapping,
    matching the paper's database segmentation).
    """
    stride = stride or window
    fwd = jax.jit(lambda p, b: model.forward(p, b, cfg, ctx,
                                             return_hidden=True))
    feats, meta = [], []
    for sid, toks in enumerate(token_seqs):
        toks = np.asarray(toks)[None, :]
        h = np.asarray(fwd(params, {"tokens": jnp.asarray(toks)})[0],
                       np.float32)  # (S, d)
        for start in range(0, h.shape[0] - window + 1, stride):
            w = h[start:start + window].mean(axis=0)
            feats.append(w)
            meta.append(Window(seq_id=sid, start=start, length=window))
    out = np.stack(feats)
    if normalize:
        out /= np.maximum(np.linalg.norm(out, axis=1, keepdims=True), 1e-9)
    return out, meta


class EmbeddingRetriever:
    """Reference net over pooled hidden-state windows (Euclidean)."""

    def __init__(self, vectors: np.ndarray, meta: List[Window], *,
                 eps_prime: float = 0.05, num_max: Optional[int] = 5,
                 tight_bounds: bool = True):
        self.meta = meta
        dist = get("euclidean")
        # each "sequence" is one pooled vector: (N, d) -> length-d series? no:
        # treat each vector as a length-1 sequence of d-dim elements so the
        # registry distance applies; equivalently plain L2 over (N, d).
        self.counter = CountedDistance(dist, vectors[:, None, :])
        # bulk-loaded: embedding corpora are built in one shot, so the
        # cohort loader's dispatch collapse applies directly
        self.net = ReferenceNet(dist, vectors[:, None, :],
                                eps_prime=eps_prime, num_max=num_max,
                                tight_bounds=tight_bounds,
                                counter=self.counter).build_batched()

    def query(self, vec: np.ndarray, eps: float) -> List[Tuple[Window, int]]:
        hits = self.net.range_query(vec[None, :], eps)
        return [(self.meta[i], i) for i in hits]

    def nearest(self, vec: np.ndarray, eps_max: float = 2.0,
                tol: float = 1e-3):
        lo, hi = 0.0, eps_max
        if not self.query(vec, hi):
            return None
        while hi - lo > tol:
            mid = 0.5 * (lo + hi)
            if self.query(vec, mid):
                hi = mid
            else:
                lo = mid
        hits = self.query(vec, hi)
        ds = self.counter.eval(vec[None, :], [i for _, i in hits])
        best = int(np.argmin(ds))
        return hits[best][0], float(ds[best])
