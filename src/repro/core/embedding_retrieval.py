"""Embedding-space subsequence retrieval — the paper's framework applied to
model hidden states (the integration point between the two halves of this
system; DESIGN.md §2).

Hidden-state windows are fixed-length sequences over (R^d, L2); Euclidean is
metric AND consistent (paper §4), so the full stack applies: windows ->
reference net -> range/NN queries.  Because the windows all share one
length, the degenerate-but-legal Euclidean case of the framework applies
(paper §5 notes its alignment rigidity; for same-length embedding windows
that rigidity is exactly what's wanted).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.segmentation import Window
from repro.models.layers import Ctx, NOCTX


#: jitted forward per (model, cfg, ctx) — one trace per token length across
#: repeated embedding sweeps instead of a fresh trace per embed_windows call
_FWD_CACHE: dict = {}


def _forward_fn(model, cfg, ctx: Ctx):
    key = (id(model), id(cfg), id(ctx))
    if key not in _FWD_CACHE:
        _FWD_CACHE[key] = jax.jit(
            lambda p, b: model.forward(p, b, cfg, ctx, return_hidden=True))
    return _FWD_CACHE[key]


def embed_windows(model, params, cfg, token_seqs: Sequence[np.ndarray],
                  window: int, *, ctx: Ctx = NOCTX, stride: Optional[int] = None,
                  normalize: bool = True) -> Tuple[np.ndarray, List[Window]]:
    """Run the model, mean-pool hidden states over fixed windows.

    Returns (windows (N, d) float32, metadata).  Window = contiguous span of
    ``window`` tokens; stride defaults to the window (non-overlapping,
    matching the paper's database segmentation).
    """
    stride = stride or window
    fwd = _forward_fn(model, cfg, ctx)
    seqs = [np.asarray(t) for t in token_seqs]
    # one stacked forward per token length: sequences sharing a shape ride a
    # single dispatch (and a single trace) instead of one call each
    by_len: dict = {}
    for sid, toks in enumerate(seqs):
        by_len.setdefault(toks.shape[0], []).append(sid)
    hidden: dict = {}
    for sids in by_len.values():
        hs = np.asarray(
            fwd(params, {"tokens": jnp.asarray(np.stack([seqs[i] for i in sids]))}),
            np.float32)  # (B, S, d)
        for row, sid in enumerate(sids):
            hidden[sid] = hs[row]
    feats, meta = [], []
    for sid in range(len(seqs)):
        h = hidden[sid]  # (S, d)
        for start in range(0, h.shape[0] - window + 1, stride):
            w = h[start:start + window].mean(axis=0)
            feats.append(w)
            meta.append(Window(seq_id=sid, start=start, length=window))
    out = np.stack(feats)
    if normalize:
        out /= np.maximum(np.linalg.norm(out, axis=1, keepdims=True), 1e-9)
    return out, meta


class EmbeddingRetriever:
    """Reference net over pooled hidden-state windows (Euclidean).

    Deprecated as a *direct* public entry point since v0.1 — this is now a
    thin shim over the facade's ``index='embedding'`` kind::

        repro.retrieval.Retriever.build(
            RetrievalConfig("euclidean", index="embedding",
                            eps_prime=..., num_max=5,
                            tight_bounds=True), vectors)

    The facade delegates here, so behavior and counts are identical; this
    constructor shim will be removed in v0.2.
    """

    def __init__(self, vectors: np.ndarray, meta: List[Window], *,
                 eps_prime: float = 0.05, num_max: Optional[int] = 5,
                 tight_bounds: bool = True):
        from repro.core import _deprecation
        from repro.retrieval import RetrievalConfig, Retriever
        _deprecation.warn_legacy("EmbeddingRetriever")
        self.meta = meta
        # each vector is a length-1 sequence of d-dim elements so the
        # registry distance applies (the facade's "embedding" data prep);
        # bulk-loaded: embedding corpora are built in one shot, so the
        # cohort loader's dispatch collapse applies directly
        self.retriever = Retriever.build(
            RetrievalConfig("euclidean", index="embedding",
                            eps_prime=eps_prime, num_max=num_max,
                            tight_bounds=tight_bounds), np.asarray(vectors))
        self.net = self.retriever.index
        self.counter = self.net.counter

    def query(self, vec: np.ndarray, eps: float) -> List[Tuple[Window, int]]:
        hits = self.retriever.query(vec).range(eps)
        return [(self.meta[i], i) for i in hits]

    def nearest(self, vec: np.ndarray, eps_max: float = 2.0,
                tol: float = 1e-3):
        rs = self.retriever.query(vec).nearest(eps_max, tol=tol)
        if not rs:
            return None
        return self.meta[rs.first], rs.distances[0]
