"""The 5-step subsequence matching framework (paper §7).

  1. partition each database sequence into windows of length l = lambda/2;
  2. build the index (reference net / cover tree / MV / linear scan);
  3. extract query segments of lengths l-lambda0 .. l+lambda0;
  4. range-query every segment against the window index;
  5. generate candidate supersequence pairs around each (segment, window)
     hit and verify them.

Query types (paper §3.2):
  I   range:   all similar pairs (|SX|,|SQ| >= lambda, ||SX|-|SQ|| <= lambda0,
               delta <= eps) within the step-5 candidate envelope;
  II  longest: maximize |SQ| via consecutive-window chaining (§7);
  III nearest: minimize delta via binary search on eps over segment hits.

Distance requirements are enforced per the paper: consistency for the
filter (any registered alignment distance), metricity additionally for the
indexed path — DTW routes to the linear-scan filter automatically.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import _deprecation, batch_engine
from repro.core import segmentation as seg
from repro.core.counter import CountedDistance
from repro.distances import base as dist_base
from repro.distances import np_backend


@dataclasses.dataclass(frozen=True)
class MatchPair:
    seq_id: int
    x_start: int
    x_len: int
    q_start: int
    q_len: int
    distance: float

    def key(self) -> Tuple[int, int, int, int, int]:
        return (self.seq_id, self.x_start, self.x_len, self.q_start, self.q_len)


@dataclasses.dataclass(frozen=True)
class SegmentHit:
    """Step-4 output: query segment matched to a database window."""
    segment: seg.Segment
    window_idx: int
    window: seg.Window
    distance: float


class LinearScanIndex:
    """Counted linear scan over all windows — the naive baseline, and the
    only legal path for consistent-but-non-metric distances (DTW, §5)."""

    def __init__(self, dist: Union[str, dist_base.Distance],
                 data: np.ndarray, *,
                 counter: Optional[CountedDistance] = None):
        dist = dist_base.resolve(dist)
        self.dist = dist
        self.counter = counter or CountedDistance(self.dist, data)
        self.data = self.counter.data

    def build(self):
        return self

    def range_query(self, q, eps, q_len=None, *,
                    lb_cascade=False) -> List[int]:
        return batch_engine.drive(self.range_query_plan(eps), self.counter,
                                  q, q_len, eps=eps, lb_cascade=lb_cascade)

    def range_query_plan(self, eps: float) -> batch_engine.Plan:
        """Single verdict frontier over the whole database."""
        ds = yield batch_engine.Frontier(np.arange(len(self.data)),
                                         batch_engine.VERDICT)
        return sorted(int(i) for i in np.nonzero(np.asarray(ds) <= eps)[0])


@dataclasses.dataclass(frozen=True)
class _IndexTuning:
    """Config-shaped view over the matcher's index knobs, so the registry's
    per-kind ``tuning`` mapping is the single source of constructor kwargs
    for both the matcher and the facade."""
    eps_prime: float
    num_max: Optional[int]
    tight_bounds: bool
    mv_refs: int


class SubsequenceMatcher:
    """The 5-step pipeline.  Deprecated as a *direct* public entry point
    since v0.1 — build through the facade instead::

        repro.retrieval.Retriever.build(
            RetrievalConfig(dist, lam=..., lambda0=...), seqs)

    The facade delegates here, so behavior and counts are identical; this
    constructor shim will be removed in v0.2."""

    def __init__(self, dist: Union[str, dist_base.Distance], lam: int,
                 lambda0: int = 1, *,
                 index: str = "refnet", eps_prime: float = 1.0,
                 num_max: Optional[int] = None, tight_bounds: bool = False,
                 mv_refs: int = 5, backend: str = "numpy",
                 lb_cascade=False, batched: bool = True,
                 bulk_build: bool = True,
                 kernel_exec: Optional[str] = None,
                 kernel_tile: Optional[int] = None):
        _deprecation.warn_legacy("SubsequenceMatcher")
        from repro.retrieval import registry as retrieval_registry
        self.dist = dist_base.require_consistent(dist)
        self.index_spec = retrieval_registry.resolve_index(index)
        if self.index_spec.requires_metric:
            dist_base.require_metric(self.dist)
        self.lam = lam
        self.lambda0 = lambda0
        self.l = seg.window_length(lam)
        self.index_kind = index
        self.backend = backend
        self.kernel_exec = kernel_exec
        self.kernel_tile = kernel_tile
        self.lb_cascade = lb_cascade
        self.batched = batched  # False = legacy per-segment host traversal
        self.bulk_build = bulk_build
        # registry tuning: constructor kwargs are derived from one
        # config-shaped view, the same mapping the facade uses
        self.index_kwargs: Dict = dict(self.index_spec.tuning(
            _IndexTuning(eps_prime=eps_prime, num_max=num_max,
                         tight_bounds=tight_bounds, mv_refs=mv_refs)))
        self.seqs: List[np.ndarray] = []
        self.windows: Optional[np.ndarray] = None
        self.meta: List[seg.Window] = []
        self.index = None
        self.engine: Optional[batch_engine.BatchEngine] = None
        self._verify_batch = None
        self._flat = None        # cached FlatNet (see flat_net())
        self._flat_level = None  # pivot_level the cache was built with

    # -- steps 1-2 (offline) -------------------------------------------------

    def build(self, seqs: Sequence[np.ndarray]) -> "SubsequenceMatcher":
        """Steps 1-2: window the sequences and build the index.

        The metric hierarchies (refnet / covertree) are bulk-loaded through
        the frontier engine (``build_batched`` — cohorts of concurrent
        insert plans, one merged dispatch per descent level); construction
        cost lands in the counter's ``build`` bucket, so ``eval_count`` /
        ``dispatch_count`` report query work only.
        """
        self.seqs = [np.asarray(x) for x in seqs]
        self.windows, self.meta = seg.partition_windows(self.seqs, self.lam)
        counter = CountedDistance(self.dist, self.windows,
                                  backend=self.backend,
                                  kernel_exec=self.kernel_exec,
                                  kernel_tile=self.kernel_tile)
        index = self.index_spec.factory(self.dist, self.windows,
                                        counter=counter, **self.index_kwargs)
        if self.index_spec.bulk and self.bulk_build:
            self.index = index.build_batched()
        else:
            self.index = index.build()
        self.engine = batch_engine.BatchEngine(self.index.counter,
                                               lb_cascade=self.lb_cascade)
        self._verify_batch = np_backend.batch_for(self.dist.name)
        self._flat = None
        self._flat_level = None
        return self

    def flat_net(self, pivot_level: Optional[int] = None):
        """Device-side view of the freshly built net (cached).

        Hands the bulk-built reference net straight to
        ``core.distributed.device_range_query``: ``flatten_net`` reuses the
        net's stored link distances and one stacked dispatch for the rest,
        so no second pair-at-a-time host pass happens here."""
        assert self.index_kind in ("refnet", "covertree"), \
            "only the metric hierarchies flatten to a FlatNet"
        if self._flat is None or self._flat_level != pivot_level:
            from repro.core.distributed import flatten_net
            self._flat = flatten_net(self.index, pivot_level)
            self._flat_level = pivot_level
        return self._flat

    @property
    def eval_count(self) -> int:
        return self.index.counter.count

    @property
    def dispatch_count(self) -> int:
        return self.index.counter.dispatches

    def reset_counter(self) -> None:
        self.index.counter.reset()

    # -- steps 3-4 (online filter) --------------------------------------------

    def segment_hits(self, Q: np.ndarray, eps: float) -> List[SegmentHit]:
        """Step 4: range-query every segment against the window index.

        Batched mode drives ALL segments — every length bucket at once —
        through one frontier-engine run: each merged round is one packed
        ``Distance.batch`` dispatch (``kernels/dispatch.py`` bucket-sorts
        the rows device-side) instead of one per round per bucket.  Hit
        sets and exact-eval counts are identical to the legacy per-segment
        loop (property-tested in tests/test_batch_engine.py).
        """
        Q = np.asarray(Q)
        hits: List[SegmentHit] = []
        buckets = seg.query_segments(Q, self.lam, self.lambda0)
        if self.batched:
            rows: List[np.ndarray] = []
            segs_all: List[seg.Segment] = []
            for ln, (arr, segs) in buckets.items():
                rows.extend(np.asarray(a) for a in arr)
                segs_all.extend(segs)
            plans = [self.index.range_query_plan(eps) for _ in rows]
            per_seg = self.engine.run(plans, rows, eps) if plans else []
            for s, wins in zip(segs_all, per_seg):
                for w in wins:
                    hits.append(SegmentHit(
                        segment=s, window_idx=int(w), window=self.meta[w],
                        distance=math.nan))
            return hits
        for ln, (arr, segs) in buckets.items():
            # lint: allow[dispatch-in-loop] -- legacy batched=False path kept as the sequential parity reference for the engine tests
            per_seg = [self.index.range_query(
                a, eps, q_len=ln, lb_cascade=self.lb_cascade)
                for a in arr]
            for s, wins in zip(segs, per_seg):
                for w in wins:
                    hits.append(SegmentHit(
                        segment=s, window_idx=int(w), window=self.meta[w],
                        distance=math.nan))
        return hits

    # -- step 5: candidate generation + verification ---------------------------

    def _candidates_for_hit(self, Q: np.ndarray, hit: SegmentHit
                            ) -> List[Tuple[int, int, int, int, int]]:
        """Candidate (seq_id, xs, xe, qs, qe) around one hit (paper §7)."""
        l, lam, l0 = self.l, self.lam, self.lambda0
        a = hit.segment.start
        b = hit.segment.start + hit.segment.length  # exclusive end
        c = hit.window.start
        X = self.seqs[hit.window.seq_id]
        nQ, nX = len(Q), len(X)
        out = []
        for qs in range(max(0, a - l - l0), a + 1):
            for qe in range(b, min(nQ, b + l + l0) + 1):
                qlen = qe - qs
                if qlen < lam:
                    continue
                for xs in range(max(0, c - l), c + 1):
                    for xe in range(c + l, min(nX, c + lam) + 1):
                        xlen = xe - xs
                        if xlen < lam or abs(xlen - qlen) > l0:
                            continue
                        out.append((hit.window.seq_id, xs, xe, qs, qe))
        return out

    def _verify(self, Q: np.ndarray, cands: Sequence[Tuple[int, int, int, int, int]]
                ) -> List[MatchPair]:
        """Batched distance verification of candidate pairs."""
        if not cands:
            return []
        Lx = max(xe - xs for _, xs, xe, _, _ in cands)
        Lq = max(qe - qs for _, _, _, qs, qe in cands)
        is_str = self.dist.string
        shp = (len(cands), Lx) if is_str else (len(cands), Lx) + self.seqs[0].shape[1:]
        xs_arr = np.zeros(shp, self.seqs[0].dtype)
        shq = (len(cands), Lq) if is_str else (len(cands), Lq) + self.seqs[0].shape[1:]
        qs_arr = np.zeros(shq, Q.dtype)
        lx = np.zeros(len(cands), np.int64)
        lq = np.zeros(len(cands), np.int64)
        for i, (sid, x0, x1, q0, q1) in enumerate(cands):
            xs_arr[i, : x1 - x0] = self.seqs[sid][x0:x1]
            qs_arr[i, : q1 - q0] = Q[q0:q1]
            lx[i] = x1 - x0
            lq[i] = q1 - q0
        ds = np.asarray(self._verify_batch(qs_arr, xs_arr, lq, lx))
        return [MatchPair(sid, x0, x1 - x0, q0, q1 - q0, float(d))
                for (sid, x0, x1, q0, q1), d in zip(cands, ds)]

    # -- query type I -----------------------------------------------------------

    def query_range(self, Q: np.ndarray, eps: float) -> List[MatchPair]:
        Q = np.asarray(Q)
        hits = self.segment_hits(Q, eps)
        cands = sorted({c for h in hits for c in self._candidates_for_hit(Q, h)})
        verified = self._verify(Q, cands)
        return [m for m in verified if m.distance <= eps]

    # -- query type II ----------------------------------------------------------

    def _chains(self, hits: List[SegmentHit]) -> List[List[SegmentHit]]:
        """Concatenate consecutive-window hits (paper §7 type II step 2)."""
        by_next: Dict[Tuple[int, int, int], List[SegmentHit]] = {}
        for h in hits:
            key = (h.window.seq_id, h.window.start,
                   h.segment.start)
            by_next.setdefault(key[:2], []).append(h)
        # DP over hits: chain[h] = longest chain ending at h
        hits_sorted = sorted(
            hits, key=lambda h: (h.window.seq_id, h.window.start,
                                 h.segment.start))
        best: Dict[int, Tuple[int, Optional[int]]] = {}
        for i, h in enumerate(hits_sorted):
            best[i] = (1, None)
            for j in range(i):
                g = hits_sorted[j]
                if g.window.seq_id != h.window.seq_id:
                    continue
                if h.window.start != g.window.start + self.l:
                    continue
                step = g.segment.start + g.segment.length
                if abs(h.segment.start - step) > self.lambda0:
                    continue
                if best[j][0] + 1 > best[i][0]:
                    best[i] = (best[j][0] + 1, j)
        chains = []
        for i in sorted(best, key=lambda i: -best[i][0]):
            chain = []
            k: Optional[int] = i
            while k is not None:
                chain.append(hits_sorted[k])
                k = best[k][1]
            chains.append(list(reversed(chain)))
        return chains

    def query_longest(self, Q: np.ndarray, eps: float) -> Optional[MatchPair]:
        """Type II: maximize |SQ| s.t. delta <= eps, |SX| >= lambda,
        ||SX|-|SQ|| <= lambda0.

        Verification starts from the longest concatenated chain (§7); a
        chain that fails to verify (e.g. one spurious window hit extended it
        past the true match) backtracks into its two trimmed subchains, so
        the search remains complete over chain sub-spans.
        """
        Q = np.asarray(Q)
        hits = self.segment_hits(Q, eps)
        if not hits:
            return None
        best: Optional[MatchPair] = None
        worklist = list(self._chains(hits))
        seen_spans = set()
        while worklist:
            # longest potential first
            worklist.sort(key=self._chain_potential, reverse=True)
            chain = worklist.pop(0)
            span = (chain[0].window.seq_id,
                    chain[0].window.start, chain[-1].window.start,
                    chain[0].segment.start,
                    chain[-1].segment.start + chain[-1].segment.length)
            if span in seen_spans:
                continue
            seen_spans.add(span)
            if best is not None and self._chain_potential(chain) <= best.q_len:
                break  # nothing left can beat the incumbent
            verified = [m for m in self._verify(
                Q, self._chain_candidates(Q, chain))
                if m.distance <= eps and m.q_len >= self.lam]
            if verified:
                m = max(verified, key=lambda m: m.q_len)
                if best is None or m.q_len > best.q_len:
                    best = m
            if len(chain) > 1:
                worklist.append(chain[1:])
                worklist.append(chain[:-1])
        return best

    def _chain_potential(self, chain) -> int:
        span_q = chain[-1].segment.start + chain[-1].segment.length \
            - chain[0].segment.start
        return span_q + 2 * (self.l + self.lambda0)

    def _chain_candidates(self, Q, chain) -> List[Tuple[int, int, int, int, int]]:
        """Supersequences around a chain: the concatenated span extended by
        up to lambda/2 (+lambda0 on the query side) on each side — the
        (k+2)*lambda/2 bound of §7."""
        l, l0, lam = self.l, self.lambda0, self.lam
        sid = chain[0].window.seq_id
        X = self.seqs[sid]
        c0 = chain[0].window.start
        c1 = chain[-1].window.start + l
        a0 = chain[0].segment.start
        a1 = chain[-1].segment.start + chain[-1].segment.length
        nQ, nX = len(Q), len(X)
        out = []
        for xs in range(max(0, c0 - l), c0 + 1):
            for xe in range(c1, min(nX, c1 + l) + 1):
                if xe - xs < lam:
                    continue
                for qs in range(max(0, a0 - l - l0), a0 + 1):
                    for qe in range(a1, min(nQ, a1 + l + l0) + 1):
                        if qe - qs < lam or abs((xe - xs) - (qe - qs)) > l0:
                            continue
                        out.append((sid, xs, xe, qs, qe))
        return out

    # -- query type III -----------------------------------------------------------

    def query_nearest(self, Q: np.ndarray, eps_max: float, *,
                      tol: float = 1e-2, eps_inc: Optional[float] = None
                      ) -> Optional[MatchPair]:
        """Type III: minimize delta(SX, SQ) (binary search on eps, §7)."""
        Q = np.asarray(Q)
        lo_e, hi_e = 0.0, float(eps_max)
        if not self.segment_hits(Q, hi_e):
            return None
        # smallest eps with at least one segment hit
        while hi_e - lo_e > tol:
            mid = 0.5 * (lo_e + hi_e)
            if self.segment_hits(Q, mid):
                hi_e = mid
            else:
                lo_e = mid
        eps = hi_e
        inc = eps_inc if eps_inc is not None else max(tol, 0.25 * max(eps, tol))
        best: Optional[MatchPair] = None
        while best is None and eps <= eps_max + 1e-9:
            hits = self.segment_hits(Q, eps)
            cands = sorted({c for h in hits
                            for c in self._candidates_for_hit(Q, h)})
            verified = [m for m in self._verify(Q, cands)
                        if m.q_len >= self.lam and m.x_len >= self.lam]
            if verified:
                cand_best = min(verified, key=lambda m: m.distance)
                # by consistency the optimum's own segments hit at eps >=
                # its distance; accept once the verified optimum is within
                # the current search radius
                if cand_best.distance <= eps + tol:
                    best = cand_best
                    break
            eps += inc
        return best


# -- brute force gold standards (tests & paper-claims validation) -------------

def brute_force_range(dist: dist_base.Distance, Q, seqs, lam, lambda0, eps,
                      x_len_exact: Optional[int] = None) -> List[MatchPair]:
    """All pairs with |SX|,|SQ| >= lambda, ||SX|-|SQ|| <= lambda0,
    delta <= eps.  Exponential-ish; only for tiny inputs."""
    batch = np_backend.batch_for(dist.name)
    Q = np.asarray(Q)
    out = []
    for sid, X in enumerate(seqs):
        X = np.asarray(X)
        for xs in range(len(X)):
            for xe in range(xs + lam, len(X) + 1):
                if x_len_exact and xe - xs != x_len_exact:
                    continue
                for qs in range(len(Q)):
                    for qe in range(qs + lam, len(Q) + 1):
                        if abs((xe - xs) - (qe - qs)) > lambda0:
                            continue
                        # lint: allow[dispatch-in-loop,acct-raw-kernel-call] -- brute-force oracle: deliberately unindexed and uncounted (the gold standard the counted paths are tested against)
                        d = float(batch(Q[None, qs:qe], X[None, xs:xe])[0])
                        if d <= eps:
                            out.append(MatchPair(sid, xs, xe - xs, qs,
                                                 qe - qs, d))
    return out


def brute_force_longest(dist, Q, seqs, lam, lambda0, eps) -> Optional[MatchPair]:
    pairs = brute_force_range(dist, Q, seqs, lam, lambda0, eps)
    return max(pairs, key=lambda m: m.q_len) if pairs else None


def brute_force_nearest(dist, Q, seqs, lam, lambda0) -> Optional[MatchPair]:
    pairs = brute_force_range(dist, Q, seqs, lam, lambda0, float("inf"))
    return min(pairs, key=lambda m: m.distance) if pairs else None
