"""Counted, batched distance evaluation over a window database.

The paper's evaluation currency (§8.2) is the number of distance
computations relative to a naive linear scan; every index implementation
funnels its evaluations through :class:`CountedDistance` so the counts are
exact and comparable.  Host-mode traversal uses the numpy wavefront backend
(sequential small batches — dispatch-bound on CPU); the device path in
``core/distributed.py`` uses the Pallas kernels instead.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.distances import base as dist_base
from repro.distances import np_backend


class CountedDistance:
    """Batched distances from one query object to indexed database windows."""

    def __init__(self, dist: dist_base.Distance, data: np.ndarray):
        self.dist = dist
        self.data = np.asarray(data)
        self.n = len(self.data)
        self._batch = np_backend.batch_for(dist.name)
        self.count = 0

    def reset(self) -> None:
        self.count = 0

    def eval(self, q: np.ndarray, idxs: Sequence[int],
             q_len: Optional[int] = None) -> np.ndarray:
        """delta(q, data[i]) for i in idxs. Counts len(idxs) evaluations."""
        idxs = np.asarray(idxs, np.int64)
        if idxs.size == 0:
            return np.zeros((0,), np.float32)
        self.count += int(idxs.size)
        ys = self.data[idxs]
        q = np.asarray(q)
        L = ys.shape[1]
        qlen = len(q) if q_len is None else q_len
        if not self.dist.variable_length and qlen != L:
            raise ValueError(
                f"{self.dist.name} requires equal lengths ({qlen} != {L})")
        # The numpy wavefront backend supports rectangular (Lx != Ly) tiles.
        xs = np.repeat(q[None, :qlen], len(ys), 0)
        lx = np.full(len(ys), qlen)
        ly = np.full(len(ys), L)
        return np.asarray(self._batch(xs, ys, lx, ly), np.float32)

    def pairwise(self, i: int, idxs: Sequence[int]) -> np.ndarray:
        """delta(data[i], data[j]) for j in idxs (used at build time)."""
        return self.eval(self.data[i], idxs)
