"""Counted, batched distance evaluation over a window database.

The paper's evaluation currency (§8.2) is the number of *exact* distance
computations relative to a naive linear scan; every index implementation
funnels its evaluations through :class:`CountedDistance` so the counts are
exact and comparable.  Batch-aware accounting separates three quantities:

* ``count``      — exact O(l^2) DP evaluations (the paper's currency);
* ``dispatches`` — Python-level backend invocations.  The frontier engine
  (``core/batch_engine.py``) folds an entire round of candidates — across
  every concurrent query of a length bucket — into **one** dispatch, which
  is where the wall-clock win over pair-at-a-time traversal comes from;
* ``lb_count``   — cheap lower-bound evaluations spent by the optional LB
  cascade (never mixed into ``count``, so paper pruning ratios stay
  comparable);
* ``build_count`` / ``build_dispatches`` — the *construction* bucket: every
  evaluation spent building an index (insert descents, cohort arbitration,
  MV profiles/tables, net flattening) is charged here instead of ``count``,
  so query-time pruning ratios start clean without a ``reset()`` and build
  cost is measured in the same currency as queries
  (``benchmarks/bench_build.py``).

Backends:

* ``numpy``  — the anti-diagonal wavefront in numpy; best for the small
  sequential batches of host-mode traversal (no device dispatch overhead);
* ``jax``    — the registry's jitted ``Distance.batch`` wavefront engine;
* ``pallas`` — the kernel registry's Pallas wavefront through the packed
  ragged-bucket dispatcher (``kernels/dispatch.py``): rows of one dispatch
  may mix length buckets freely, and an optional fused ε threshold returns
  verdict-preserving masked distances.  Interpret-mode off-TPU.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.distances import base as dist_base
from repro.distances import bounds
from repro.distances import np_backend

BACKENDS = ("numpy", "jax", "pallas")

#: accounting buckets — query-time (the paper's currency) vs construction
QUERY = "query"
BUILD = "build"

from repro.kernels.registry import _pad_pow2  # one pow2 padding discipline


def _resolve_backend(dist: dist_base.Distance, backend: str,
                     kernel_exec: Optional[str] = None,
                     kernel_tile: Optional[int] = None) -> Callable:
    """A ``(xs, ys, lx, ly) -> (B,) np.ndarray`` batch function.

    ``kernel_exec``/``kernel_tile`` thread the wavefront execution mode
    and Pallas band depth into the pallas backend's packed dispatches
    (None: the kernel registry's process-wide policy / VMEM heuristic);
    the other backends ignore them."""
    if backend == "numpy":
        try:
            return np_backend.batch_for(dist.name)
        except KeyError:
            # third-party distance: no hand-written numpy wavefront —
            # fall back to the registry's own (jitted) batch callable
            return _registry_batch(dist)
    if backend == "jax":
        return _registry_batch(dist)
    if backend == "pallas":
        from repro.kernels import dispatch as kernel_dispatch
        from repro.kernels import registry as kernel_registry
        if not kernel_registry.has(dist.name):  # third-party: no kernel
            try:
                return np_backend.batch_for(dist.name)
            except KeyError:
                return _registry_batch(dist)

        def pallas_batch(xs, ys, lx=None, ly=None, eps=None):
            # packed ragged-bucket dispatch: rows may mix length buckets
            # freely (bucket-sorted, padded, ONE kernel call); ``eps``
            # engages the fused ε path — non-hit rows come back as the BIG
            # sentinel, which preserves every <= eps verdict.
            out = kernel_dispatch.packed_batch(dist.name, xs, ys, lx, ly,
                                               eps=eps, exec=kernel_exec,
                                               tile=kernel_tile)
            return out.dist

        pallas_batch.fused = True  # accepts the fused-ε keyword
        return pallas_batch
    raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")


def _registry_batch(dist: dist_base.Distance) -> Callable:
    """Wrap the registry's ``Distance.batch`` (row-padded, pow2-batched so
    jit recompilations stay rare) as a host-callable batch function."""
    import jax.numpy as jnp

    def jax_batch(xs, ys, lx=None, ly=None):
        xs, ys = np.asarray(xs), np.asarray(ys)
        if len(xs) == 0:
            return np.zeros((0,), np.float32)
        L = max(xs.shape[1], ys.shape[1])

        def pad_len(a):
            if a.shape[1] == L:
                return a
            w = [(0, 0), (0, L - a.shape[1])] + [(0, 0)] * (a.ndim - 2)
            return np.pad(a, w)

        lx = np.full(len(xs), xs.shape[1]) if lx is None else np.asarray(lx)
        ly = np.full(len(ys), ys.shape[1]) if ly is None else np.asarray(ly)
        B = len(xs)
        P = _pad_pow2(B)
        xs, ys = pad_len(xs), pad_len(ys)
        if P != B:  # pad batch with row 0 so shapes recompile rarely
            pad = P - B
            xs = np.concatenate([xs, xs[:1].repeat(pad, 0)])
            ys = np.concatenate([ys, ys[:1].repeat(pad, 0)])
            lx = np.concatenate([lx, lx[:1].repeat(pad)])
            ly = np.concatenate([ly, ly[:1].repeat(pad)])
        out = np.asarray(dist.batch(xs, ys, jnp.asarray(lx),
                                    jnp.asarray(ly)))
        return out[:B]

    return jax_batch


class CountedDistance:
    """Batched distances from query objects to indexed database windows."""

    def __init__(self, dist: dist_base.Distance, data: np.ndarray, *,
                 backend: str = "numpy",
                 kernel_exec: Optional[str] = None,
                 kernel_tile: Optional[int] = None):
        self.dist = dist
        self.data = np.asarray(data)
        self.n = len(self.data)
        self.backend = backend
        self.kernel_exec = kernel_exec
        self.kernel_tile = kernel_tile
        self._batch = _resolve_backend(dist, backend, kernel_exec,
                                       kernel_tile)
        self.count = 0       # exact evaluations (paper currency)
        self.dispatches = 0  # Python-level backend dispatches
        self.lb_count = 0    # cheap lower-bound evaluations (LB cascade)
        self.build_count = 0       # exact evaluations spent on construction
        self.build_dispatches = 0  # backend dispatches spent on construction
        #: per-tier LB accounting: rows a tier was evaluated on / pruned
        self.lb_tier_rows: dict = {}
        self.lb_tier_pruned: dict = {}
        #: lazily-built per-window envelope statistics (boxes + ERP gap
        #: masses) over ``data`` — cached for the plan's lifetime so the
        #: cascade never recomputes O(B*L) row norms per round
        self._env_cache: Optional[bounds.EnvelopeSet] = None

    def reset(self) -> None:
        self.count = 0
        self.dispatches = 0
        self.lb_count = 0
        self.build_count = 0
        self.build_dispatches = 0
        self.lb_tier_rows = {}
        self.lb_tier_pruned = {}

    def extend(self, rows: np.ndarray) -> None:
        """Append windows to the indexed database (accounting untouched).

        Existing row indices stay valid — new windows land at the end — so
        an index built over the old database can keep serving while fresh
        content is bulk-loaded on top (the elastic layer's incremental
        reshard path).  Callers holding a reference to ``.data`` must
        re-read it after this call."""
        rows = np.asarray(rows)
        if len(rows) == 0:
            return
        rows = rows.astype(self.data.dtype)
        self.data = np.concatenate([self.data, rows])
        self.n = len(self.data)
        if self._env_cache is not None:  # incremental envelope refresh
            self._env_cache.extend(bounds.build_envelopes(rows))

    def envelopes(self) -> bounds.EnvelopeSet:
        """Per-window envelope statistics over ``data`` (cached).

        Built in ONE stacked vectorized pass on first use; ``extend``
        refreshes it incrementally, so the cascade's per-candidate gap
        masses and boxes are gathered (``take``), never recomputed."""
        if self._env_cache is None:
            self._env_cache = bounds.build_envelopes(self.data)
        return self._env_cache

    def eval(self, q: np.ndarray, idxs: Sequence[int],
             q_len: Optional[int] = None, *,
             bucket: str = QUERY) -> np.ndarray:
        """delta(q, data[i]) for i in idxs. Counts len(idxs) evaluations."""
        idxs = np.asarray(idxs, np.int64)
        if idxs.size == 0:
            return np.zeros((0,), np.float32)
        q = np.asarray(q)
        qlen = len(q) if q_len is None else q_len
        qs = np.repeat(q[None, :qlen], idxs.size, 0)
        return self.eval_stacked(qs, idxs, qlen, bucket=bucket)

    @property
    def fused(self) -> bool:
        """Whether the backend supports fused ε-pruning (pallas kernels)."""
        return getattr(self._batch, "fused", False)

    def eval_stacked(self, qs: np.ndarray, idxs: Sequence[int],
                     q_len=None, *, bucket: str = QUERY,
                     eps=None, lb_tier=None) -> np.ndarray:
        """delta(qs[i], data[idxs[i]]) row-wise in ONE backend dispatch.

        ``qs`` holds one (possibly repeated) query row per candidate — the
        frontier engine concatenates every concurrent query's round into a
        single call here, so dispatches scale with rounds, not candidates.
        ``q_len`` may be a scalar or a per-row vector: the packed engine
        mixes every length bucket of a round into one dispatch.  ``eps``
        (scalar or per-row; +inf rows opt out) engages the backend's fused
        ε path when it has one — returned values keep every ``<= eps``
        verdict (non-hits come back as a quasi-infinity), and accounting is
        unchanged: each requested row is one exact evaluation, padding rows
        are never counted.

        ``lb_tier`` stages the LB cascade *inside* the round: finite-ε rows
        run the tier-0 endpoint bounds, ``"envelope"`` additionally runs the
        elementwise envelope kernel on the survivors, and only the remaining
        rows are compacted into the (single) exact dispatch — pruned rows
        come back as their bound value, which preserves every ``<= eps``
        verdict because ``lb <= delta``.  Accounting: exact rows land in
        ``count`` (no dispatch is issued when every row was pruned), bound
        rows in ``lb_count`` plus the per-tier ``lb_tier_rows`` /
        ``lb_tier_pruned`` maps.
        """
        idxs = np.asarray(idxs, np.int64)
        if idxs.size == 0:
            return np.zeros((0,), np.float32)
        qs = np.asarray(qs)
        ys = self.data[idxs]
        L = ys.shape[1]
        if q_len is None:
            lx = np.full(len(ys), qs.shape[1], np.int64)
        elif np.ndim(q_len) == 0:
            lx = np.full(len(ys), int(q_len), np.int64)
        else:
            lx = np.asarray(q_len, np.int64)
        if not self.dist.variable_length and (lx != L).any():
            bad = int(lx[(lx != L).argmax()])
            raise ValueError(
                f"{self.dist.name} requires equal lengths ({bad} != {L})")
        # Rectangular (Lx != Ly) and ragged tiles: all backends take
        # per-row length vectors.
        xs = qs[:, :int(lx.max())]
        ly = np.full(len(ys), L, np.int64)

        tier = bounds.normalize_tier(lb_tier)
        if tier != "off" and eps is not None:
            return self._cascade_stacked(xs, ys, idxs, lx, ly, eps, tier,
                                         bucket)

        if bucket == BUILD:
            self.build_count += int(idxs.size)
            self.build_dispatches += 1
        else:
            self.count += int(idxs.size)
            self.dispatches += 1
        if eps is not None and self.fused:
            return np.asarray(self._batch(xs, ys, lx, ly, eps=eps),
                              np.float32)
        return np.asarray(self._batch(xs, ys, lx, ly), np.float32)

    def _note_lb(self, tier: str, rows: int, pruned: int) -> None:
        self.lb_count += int(rows)
        self.lb_tier_rows[tier] = self.lb_tier_rows.get(tier, 0) + int(rows)
        self.lb_tier_pruned[tier] = \
            self.lb_tier_pruned.get(tier, 0) + int(pruned)

    def _cascade_stacked(self, xs, ys, idxs, lx, ly, eps, tier: str,
                         bucket: str) -> np.ndarray:
        """Tiered LB staging of one round: endpoint -> envelope -> exact.

        Rows with ``eps = +inf`` (value-consuming EXACT frontiers) opt out
        of every bound and always reach the exact dispatch; all counters see
        requested rows only (backend batch padding is sliced off below us).
        """
        B = idxs.size
        eps_v = np.broadcast_to(
            np.asarray(eps, np.float32), (B,)).astype(np.float32)
        eligible = np.isfinite(eps_v)
        alive = eligible.copy()
        lbs = np.zeros(B, np.float32)

        lb_fn = self.dist.lower_bound
        if lb_fn is not None and eligible.any():
            r = np.flatnonzero(eligible)
            kw = {}
            if self.dist.name == "erp":
                # satellite: gap masses gathered from the cached envelope
                # statistics, not recomputed O(B*L) per round
                kw["y_mass"] = self.envelopes().mass[idxs[r]]
            lb0 = np.asarray(
                lb_fn(xs[r], ys[r], lx[r], ly[r], **kw), np.float32)
            pruned0 = lb0 > eps_v[r]
            lbs[r] = np.maximum(lbs[r], lb0)
            alive[r[pruned0]] = False
            self._note_lb("endpoint", r.size, int(pruned0.sum()))

        if tier == "envelope" and alive.any() and \
                self.dist.envelope_bound is not None:
            r = np.flatnonzero(alive)
            if self.backend == "pallas":
                from repro.kernels import dispatch as kernel_dispatch
                from repro.kernels import registry as kernel_registry
                if kernel_registry.has_envelope(self.dist.name):
                    out = kernel_dispatch.packed_envelope(
                        self.dist.name, xs[r], ys[r], lx[r], ly[r],
                        eps=eps_v[r])
                    lb1 = np.asarray(out.dist, np.float32)
                else:  # third-party distance: host envelope fallback
                    lb1 = self._host_envelope(xs, ys, idxs, lx, ly, r)
            else:
                lb1 = self._host_envelope(xs, ys, idxs, lx, ly, r)
            pruned1 = lb1 > eps_v[r]
            lbs[r] = np.maximum(lbs[r], lb1)
            alive[r[pruned1]] = False
            self._note_lb("envelope", r.size, int(pruned1.sum()))

        out = lbs  # pruned rows answer with their bound (verdict-preserving)
        exact = ~eligible | alive
        n_exact = int(exact.sum())
        if n_exact:
            if bucket == BUILD:
                self.build_count += n_exact
                self.build_dispatches += 1
            else:
                self.count += n_exact
                self.dispatches += 1
            if self.fused:
                vals = self._batch(xs[exact], ys[exact], lx[exact],
                                   ly[exact], eps=eps_v[exact])
            else:
                vals = self._batch(xs[exact], ys[exact], lx[exact],
                                   ly[exact])
            out[exact] = np.asarray(vals, np.float32)
        return out

    def _host_envelope(self, xs, ys, idxs, lx, ly, r) -> np.ndarray:
        """Numpy tier-1 bound on rows ``r`` from cached candidate boxes."""
        y_env = self.envelopes().take(idxs[r])
        return np.asarray(
            self.dist.envelope_bound(xs[r], ys[r], lx[r], ly[r],
                                     y_env=y_env), np.float32)

    def lower_bounds(self, qs: np.ndarray, idxs: Sequence[int],
                     q_len=None) -> Optional[np.ndarray]:
        """Cheap row-wise lower bounds, or None when the distance has none.

        ``q_len`` scalar or per-row (packed rounds mix length buckets).
        Counted in ``lb_count`` only — never in ``count``."""
        lb = self.dist.lower_bound
        if lb is None:
            return None
        idxs = np.asarray(idxs, np.int64)
        if idxs.size == 0:
            return np.zeros((0,), np.float32)
        qs = np.asarray(qs)
        ys = self.data[idxs]
        if q_len is None:
            lx = np.full(len(ys), qs.shape[1], np.int64)
        elif np.ndim(q_len) == 0:
            lx = np.full(len(ys), int(q_len), np.int64)
        else:
            lx = np.asarray(q_len, np.int64)
        self._note_lb("endpoint", int(idxs.size), 0)
        ly = np.full(len(ys), ys.shape[1])
        kw = {}
        if self.dist.name == "erp":
            # gap masses are cached per candidate id for the plan's
            # lifetime — not recomputed O(B*L) on every round
            kw["y_mass"] = self.envelopes().mass[idxs]
        return np.asarray(
            lb(qs[:, :int(lx.max())], ys, lx, ly, **kw), np.float32)

    def pairwise(self, i: int, idxs: Sequence[int], *,
                 bucket: str = BUILD) -> np.ndarray:
        """delta(data[i], data[j]) for j in idxs (node-vs-node; charged to
        the ``build`` bucket by default — its callers are constructors)."""
        return self.eval(self.data[i], idxs, bucket=bucket)

    def eval_pairs(self, lefts: Sequence[int], rights: Sequence[int], *,
                   bucket: str = BUILD) -> np.ndarray:
        """delta(data[lefts[i]], data[rights[i]]) row-wise in ONE dispatch.

        The pairwise (node-vs-node) analogue of :meth:`eval_stacked`; used
        by bulk construction (cohort conflict arbitration, net flattening,
        MV profile/table assembly)."""
        lefts = np.asarray(lefts, np.int64)
        if lefts.size == 0:
            return np.zeros((0,), np.float32)
        return self.eval_stacked(self.data[lefts], rights, bucket=bucket)
