"""Numpy wavefront backend for *host-mode* index traversal.

The reference net / cover tree / MV index are host-side control structures
(paper §6, Appendix); their candidate batches are small (tens) and arrive
sequentially, where per-call JAX dispatch overhead would dominate on CPU.
This module evaluates the same anti-diagonal recurrences in numpy.  It is
tested against the same row-major oracles as the JAX engine; the device
(TPU) path uses the Pallas kernels instead.
"""

from __future__ import annotations

import numpy as np

BIG = np.float32(3.4e37)


def _l2_cost(xs, ys):
    diff = xs[:, :, None, :] - ys[:, None, :, :]
    return np.sqrt(np.maximum(np.sum(diff * diff, axis=-1), 0.0))


def _neq_cost(xs, ys):
    return (xs[:, :, None] != ys[:, None, :]).astype(np.float32)


def batch_alignment(xs: np.ndarray, ys: np.ndarray, mode: str,
                    len_x=None, len_y=None) -> np.ndarray:
    """(B, Lx[, d]) x (B, Ly[, d]) -> (B,) alignment distances, numpy."""
    if mode == "lev":
        xs = np.asarray(xs)
        ys = np.asarray(ys)
        cost = _neq_cost(xs, ys)
    else:
        xs = np.asarray(xs, np.float32)
        ys = np.asarray(ys, np.float32)
        if xs.ndim == 2:
            xs, ys = xs[..., None], ys[..., None]
        cost = _l2_cost(xs, ys)
    B, Lx, Ly = cost.shape
    len_x = np.full(B, Lx) if len_x is None else np.asarray(len_x)
    len_y = np.full(B, Ly) if len_y is None else np.asarray(len_y)

    if mode == "erp":
        gx = np.sqrt(np.maximum(np.sum(xs * xs, -1), 0.0))
        gy = np.sqrt(np.maximum(np.sum(ys * ys, -1), 0.0))
        pos_x = np.arange(Lx)[None, :] < len_x[:, None]
        pos_y = np.arange(Ly)[None, :] < len_y[:, None]
        gx = np.where(pos_x, gx, 0.0)
        gy = np.where(pos_y, gy, 0.0)
        border_col = np.concatenate(
            [np.zeros((B, 1), np.float32), np.cumsum(gx, 1)], 1)
        border_row = np.concatenate(
            [np.zeros((B, 1), np.float32), np.cumsum(gy, 1)], 1)
    elif mode == "lev":
        border_col = np.broadcast_to(
            np.arange(Lx + 1, dtype=np.float32)[None], (B, Lx + 1)).copy()
        border_row = np.broadcast_to(
            np.arange(Ly + 1, dtype=np.float32)[None], (B, Ly + 1)).copy()
        gx = gy = None
    else:
        border_col = np.full((B, Lx + 1), BIG, np.float32)
        border_col[:, 0] = 0.0
        border_row = np.full((B, Ly + 1), BIG, np.float32)
        border_row[:, 0] = 0.0
        gx = gy = None

    ii = np.arange(Lx + 1)
    d1 = np.full((B, Lx + 1), BIG, np.float32)
    d1[:, 0] = border_col[:, 0]
    d2 = np.full((B, Lx + 1), BIG, np.float32)
    res = np.where(len_x + len_y == 0, d1[:, 0], BIG).astype(np.float32)
    target = len_x + len_y
    rows = np.arange(B)

    for k in range(1, Lx + Ly + 1):
        ci = ii - 1
        cj = k - ii - 1
        valid = (ci >= 0) & (cj >= 0) & (ci < Lx) & (cj < Ly)
        c = np.zeros((B, Lx + 1), np.float32)
        c[:, valid] = cost[:, ci[valid], cj[valid]]
        dd = np.concatenate([np.full((B, 1), BIG, np.float32), d2[:, :-1]], 1)
        du = np.concatenate([np.full((B, 1), BIG, np.float32), d1[:, :-1]], 1)
        dl = d1
        if mode == "dtw":
            new = c + np.minimum(dd, np.minimum(du, dl))
        elif mode == "dfd":
            new = np.maximum(c, np.minimum(dd, np.minimum(du, dl)))
        elif mode == "lev":
            new = np.minimum(dd + c, np.minimum(du + 1.0, dl + 1.0))
        else:  # erp
            cu = np.concatenate([np.zeros((B, 1), np.float32), gx], 1)
            cl = np.zeros((B, Lx + 1), np.float32)
            vj = (cj >= 0) & (cj < Ly)
            cl[:, vj] = gy[:, cj[vj]]
            new = np.minimum(dd + c, np.minimum(du + cu, dl + cl))
        if k <= Lx:
            new[:, k] = border_col[:, k]
        new[:, 0] = border_row[:, k] if k <= Ly else BIG
        new[:, (ii > k) | (ii < k - Ly)] = BIG
        hit = target == k
        if hit.any():
            res[hit] = new[rows[hit], len_x[hit]]
        d2 = d1
        d1 = new
    return res


def batch_euclidean(xs, ys, len_x=None, len_y=None):
    xs = np.asarray(xs, np.float32)
    ys = np.asarray(ys, np.float32)
    if xs.ndim == 2:
        xs, ys = xs[..., None], ys[..., None]
    B, L = xs.shape[0], xs.shape[1]
    lx = np.full(B, L) if len_x is None else np.asarray(len_x)
    mask = (np.arange(L)[None, :] < lx[:, None]).astype(np.float32)
    d2 = np.sum(np.sum((xs - ys) ** 2, -1) * mask, -1)
    return np.sqrt(np.maximum(d2, 0.0))


def batch_hamming(xs, ys, len_x=None, len_y=None):
    xs, ys = np.asarray(xs), np.asarray(ys)
    B, L = xs.shape
    lx = np.full(B, L) if len_x is None else np.asarray(len_x)
    mask = np.arange(L)[None, :] < lx[:, None]
    return np.sum((xs != ys) & mask, -1).astype(np.float32)


_MODE_OF = {"dtw": "dtw", "erp": "erp", "frechet": "dfd", "levenshtein": "lev"}


def batch_for(name: str):
    """Numpy batch function matching a registry distance name."""
    if name == "euclidean":
        return batch_euclidean
    if name == "hamming":
        return batch_hamming
    if name in _MODE_OF:
        mode = _MODE_OF[name]
        return lambda xs, ys, lx=None, ly=None: batch_alignment(
            xs, ys, mode, lx, ly)
    raise KeyError(name)


def matrix_for(name: str):
    """Numpy all-pairs function with the registry ``matrix`` signature.

    Completes host-side parity with ``Distance.batch``/``Distance.matrix``:
    (M, Lx[, d]) x (N, Ly[, d]) -> (M, N), realized by tiling into one
    paired batch so the wavefront runs once over all M*N cells.
    """
    batch = batch_for(name)

    def matrix(xs, ys, len_x=None, len_y=None):
        xs, ys = np.asarray(xs), np.asarray(ys)
        M, N = len(xs), len(ys)
        xt = np.repeat(xs, N, axis=0)
        yt = np.tile(ys, (M,) + (1,) * (ys.ndim - 1))
        lx = None if len_x is None else np.repeat(np.asarray(len_x), N)
        ly = None if len_y is None else np.tile(np.asarray(len_y), M)
        return batch(xt, yt, lx, ly).reshape(M, N)

    return matrix
