from repro.distances.base import Distance, get, names, require_consistent, require_metric  # noqa: F401
