from repro.distances.base import (  # noqa: F401
    Distance, get, names, register, resolve, require_consistent,
    require_metric)
