"""Slow, obviously-correct numpy oracles for every registered distance.

Used by unit/property tests and by ``kernels/ref.py`` sanity checks.  These
are straight transcriptions of the textbook row-major DPs.
"""

from __future__ import annotations

import numpy as np

INF = float("inf")


def _elem(a, b):
    a, b = np.atleast_1d(np.asarray(a, np.float64)), np.atleast_1d(np.asarray(b, np.float64))
    return float(np.sqrt(np.sum((a - b) ** 2)))


def euclidean_oracle(x, y):
    x, y = np.asarray(x, np.float64), np.asarray(y, np.float64)
    assert x.shape[0] == y.shape[0]
    return float(np.sqrt(np.sum((x - y) ** 2)))


def hamming_oracle(x, y):
    x, y = np.asarray(x), np.asarray(y)
    assert x.shape[0] == y.shape[0]
    return float(np.sum(x != y))


def dtw_oracle(x, y):
    n, m = len(x), len(y)
    D = np.full((n + 1, m + 1), INF)
    D[0, 0] = 0.0
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            c = _elem(x[i - 1], y[j - 1])
            D[i, j] = c + min(D[i - 1, j], D[i, j - 1], D[i - 1, j - 1])
    return float(D[n, m])


def erp_oracle(x, y, g=0.0):
    n, m = len(x), len(y)
    D = np.zeros((n + 1, m + 1))
    for i in range(1, n + 1):
        D[i, 0] = D[i - 1, 0] + _elem(x[i - 1], g)
    for j in range(1, m + 1):
        D[0, j] = D[0, j - 1] + _elem(y[j - 1], g)
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            D[i, j] = min(
                D[i - 1, j - 1] + _elem(x[i - 1], y[j - 1]),
                D[i - 1, j] + _elem(x[i - 1], g),
                D[i, j - 1] + _elem(y[j - 1], g),
            )
    return float(D[n, m])


def frechet_oracle(x, y):
    n, m = len(x), len(y)
    D = np.full((n + 1, m + 1), INF)
    D[0, 0] = 0.0
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            c = _elem(x[i - 1], y[j - 1])
            D[i, j] = max(c, min(D[i - 1, j], D[i, j - 1], D[i - 1, j - 1]))
    return float(D[n, m])


def levenshtein_oracle(x, y):
    n, m = len(x), len(y)
    D = np.zeros((n + 1, m + 1))
    D[:, 0] = np.arange(n + 1)
    D[0, :] = np.arange(m + 1)
    for i in range(1, n + 1):
        for j in range(1, m + 1):
            c = 0.0 if x[i - 1] == y[j - 1] else 1.0
            D[i, j] = min(D[i - 1, j - 1] + c, D[i - 1, j] + 1, D[i, j - 1] + 1)
    return float(D[n, m])


ORACLES = {
    "euclidean": euclidean_oracle,
    "hamming": hamming_oracle,
    "dtw": dtw_oracle,
    "erp": erp_oracle,
    "frechet": frechet_oracle,
    "levenshtein": levenshtein_oracle,
}
