"""Distance registry: every sequence distance used by the framework.

The paper (§4) classifies distances along two axes that this registry makes
explicit and machine-checkable:

* ``consistent`` — Def. 1: for every subsequence SX of X there is a
  subsequence SQ of Q with delta(SQ, SX) <= delta(Q, X).  Required by the
  segmentation filter (Lemmas 1-3).
* ``metric`` — triangle inequality + symmetry.  Required by the metric
  indexes (reference net, cover tree, MV reference indexing).

DTW is consistent but NOT metric (paper §5), so the registry lets the
matching pipeline accept it while the index constructors reject it.

Sequences are arrays:

* time series: ``(l, d)`` float arrays (d >= 1);
* strings:     ``(l,)`` integer arrays over a finite alphabet.

Batched signatures (the only ones used on the hot path):

* ``pair(x, y, len_x=None, len_y=None)``            -> scalar
* ``batch(xs, ys, len_x=None, len_y=None)``          -> (B,)   paired
* ``matrix(xs, ys, len_x=None, len_y=None)``         -> (M, N) all pairs

Alignment distances may additionally register a cheap numpy ``lower_bound``
with the ``batch`` signature (``distances/bounds.py``); the frontier engine
uses it to skip exact O(l^2) DPs for candidates whose bound already exceeds
the query radius.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Union

import jax.numpy as jnp

_REGISTRY: Dict[str, "Distance"] = {}


@dataclasses.dataclass(frozen=True)
class Distance:
    """A registered sequence distance."""

    name: str
    #: paired batch: (B,l,d)/(B,l) x2 -> (B,)
    batch: Callable
    #: all-pairs: (M,...),(N,...) -> (M,N)
    matrix: Callable
    metric: bool
    consistent: bool
    #: operates on integer token sequences (strings) rather than R^d series
    string: bool
    #: supports unequal lengths (alignment-based distances)
    variable_length: bool
    doc: str = ""
    #: optional vectorized numpy lower bound, row-wise <= batch(...); used by
    #: the batch engine's LB cascade.  None = no cheap bound available.
    lower_bound: Optional[Callable] = None
    #: optional tier-1 envelope bound (LB_Keogh lineage, O(B*L) elementwise;
    #: ``distances/bounds.py``): same signature plus a ``y_env`` keyword for
    #: precomputed per-candidate envelope statistics.  None = the cascade's
    #: ``"envelope"`` tier falls back to the endpoint tier alone.
    envelope_bound: Optional[Callable] = None

    def pair(self, x, y, len_x=None, len_y=None):
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        len_x = x.shape[0] if len_x is None else len_x
        len_y = y.shape[0] if len_y is None else len_y
        L = max(x.shape[0], y.shape[0])
        x = _pad_to(x, L)
        y = _pad_to(y, L)
        return self.batch(x[None], y[None],
                          jnp.asarray([len_x]), jnp.asarray([len_y]))[0]


def _pad_to(x: jnp.ndarray, L: int) -> jnp.ndarray:
    if x.shape[0] == L:
        return x
    pad = [(0, L - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def register(dist: Distance) -> Distance:
    if dist.name in _REGISTRY:
        raise ValueError(f"distance {dist.name!r} already registered")
    _REGISTRY[dist.name] = dist
    return dist


def get(name: str) -> Distance:
    # import for registration side effects
    from repro.distances import lp, dtw, erp, frechet, levenshtein  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown distance {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names():
    from repro.distances import lp, dtw, erp, frechet, levenshtein  # noqa: F401

    return sorted(_REGISTRY)


def resolve(dist: Union[str, Distance]) -> Distance:
    """Accept a registry name or a ``Distance`` instance interchangeably.

    Every index / facade constructor funnels its ``dist`` argument through
    here, so callers never have to care which form they hold.  An instance
    that was never registered is returned as-is (third-party distances can
    be used without touching the global registry).
    """
    if isinstance(dist, Distance):
        return dist
    return get(dist)


def require_metric(dist: Union[str, Distance]) -> Distance:
    """Fetch a distance for use inside a metric index (paper §5, §6).

    Raises if the distance does not obey the triangle inequality — e.g. DTW,
    which the paper explicitly excludes from the indexed path.
    """
    d = resolve(dist)
    if not d.metric:
        raise ValueError(
            f"distance {d.name!r} is not a metric; the reference net / cover "
            "tree / MV index require metricity (paper §5). Use the "
            "segmentation filter with a linear scan instead."
        )
    return d


def require_consistent(dist: Union[str, Distance]) -> Distance:
    """Fetch a distance for use with the segmentation filter (Lemmas 1-3)."""
    d = resolve(dist)
    if not d.consistent:
        raise ValueError(
            f"distance {d.name!r} is not consistent; the segmentation filter "
            "requires consistency (paper Def. 1)."
        )
    return d
