"""Euclidean and Hamming distances (equal-length, no alignment).

Both are metric and consistent (paper §4) but cannot tolerate temporal
misalignment — the paper notes this makes them a poor fit for subsequence
matching with shifts (§5); they remain first-class citizens here because the
embedding-retrieval integration uses Euclidean over fixed-length hidden-state
windows, where lengths always agree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distances import base
from repro.distances._wavefront import default_lengths, matrixify


@jax.jit
def euclidean_batch(xs, ys, len_x=None, len_y=None):
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if xs.ndim == 2:
        xs, ys = xs[..., None], ys[..., None]
    B, L = xs.shape[0], xs.shape[1]
    lx = default_lengths(xs, len_x)
    mask = (jnp.arange(L)[None, :] < lx[:, None]).astype(jnp.float32)
    d2 = jnp.sum(jnp.sum((xs - ys) ** 2, axis=-1) * mask, axis=-1)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@jax.jit
def euclidean_matrix(xs, ys, len_x=None, len_y=None):
    """All-pairs Euclidean via the ||x||^2 + ||y||^2 - 2 x.y identity (MXU)."""
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    xf = xs.reshape(xs.shape[0], -1)
    yf = ys.reshape(ys.shape[0], -1)
    xn = jnp.sum(xf * xf, axis=1)
    yn = jnp.sum(yf * yf, axis=1)
    d2 = xn[:, None] + yn[None, :] - 2.0 * (xf @ yf.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@jax.jit
def hamming_batch(xs, ys, len_x=None, len_y=None):
    xs = jnp.asarray(xs, jnp.int32)
    ys = jnp.asarray(ys, jnp.int32)
    B, L = xs.shape
    lx = default_lengths(xs, len_x)
    mask = jnp.arange(L)[None, :] < lx[:, None]
    return jnp.sum((xs != ys) & mask, axis=-1).astype(jnp.float32)


euclidean = base.register(base.Distance(
    name="euclidean",
    batch=euclidean_batch,
    matrix=euclidean_matrix,
    metric=True,
    consistent=True,
    string=False,
    variable_length=False,
    doc="L2 over equal-length sequences; metric",
))

hamming = base.register(base.Distance(
    name="hamming",
    batch=hamming_batch,
    matrix=matrixify(hamming_batch),
    metric=True,
    consistent=True,
    string=True,
    variable_length=False,
    doc="Hamming over equal-length token sequences; metric",
))
