"""Cheap, provable lower bounds for the batch engine's LB cascade.

Each function is vectorized numpy over row-paired batches and satisfies
``lb(x, y) <= delta(x, y)`` row-wise, so pruning a candidate whose bound
already exceeds eps can never change a range-query verdict — only skip its
exact O(l^2) DP.

The cascade has two tiers (``LB_TIERS``; :func:`normalize_tier` maps the
legacy booleans onto them):

* **tier 0 — endpoint** (O(B) per batch): the historic LB_Kim-style
  endpoint / global-gap-mass bounds below.

  - DTW — every warping path aligns (1,1) and (lx,ly); both cells carry
    nonnegative cost and are distinct whenever lx+ly > 2, so the sum of
    the two endpoint costs lower-bounds the path sum (LB_Kim first/last).
  - DFD — the Frechet value is the *max* over an aligning path through the
    same two mandatory cells, so the larger endpoint cost is a bound.
  - ERP — with gap element g = 0, ERP(x, y) >= | sum_i |x_i| - sum_j |y_j| |
    (Chen & Ng, VLDB'04): every edit script pays at least the difference of
    total gap masses.
  - Levenshtein — at least |lx - ly| insertions/deletions are unavoidable.

* **tier 1 — envelope** (O(B*L) elementwise, LB_Keogh lineage): per-window
  upper/lower envelopes (an axis-aligned bounding box per candidate — the
  warping band of our unconstrained alignments is the full sequence, so the
  per-position Keogh envelope degenerates to the per-window box, which is
  exactly what makes it precomputable as two (N, d) arrays on ``FlatNet``).
  See ``lb_dtw_envelope`` / ``lb_erp_envelope`` / ``lb_frechet_envelope``
  for the per-distance soundness proofs.

:class:`EnvelopeSet` holds the precomputed per-window envelope statistics
(box + ERP gap-mass prefix sums), built in ONE vectorized pass by
:func:`build_envelopes`; ``CountedDistance`` caches one per database and
``FlatNet`` stores one for the device / fleet paths.

Signature: ``(xs, ys, len_x, len_y) -> (B,)`` with ``xs: (B, Lx[, d])``,
``ys: (B, Ly[, d])`` and integer length vectors (rows may be padded).
Envelope-tier functions additionally accept ``y_env`` (an
:class:`EnvelopeSet` row-sliced to the batch) so per-candidate statistics
are gathered, never recomputed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

#: tiered LB-cascade policy values (config + engines); the legacy booleans
#: map False -> "off", True -> "endpoint".
LB_TIERS = ("off", "endpoint", "envelope")


def normalize_tier(value) -> str:
    """Map a legacy boolean or a tier name onto ``LB_TIERS``."""
    if value is None or value is False:
        return "off"
    if value is True:
        return "endpoint"
    if value in LB_TIERS:
        return value
    raise ValueError(
        f"lb_cascade must be a bool or one of {LB_TIERS}; got {value!r}")


def _as3d(a: np.ndarray) -> np.ndarray:
    a = np.asarray(a, np.float32)
    return a[..., None] if a.ndim == 2 else a


def _row_norm(a: np.ndarray) -> np.ndarray:
    """(B, L, d) -> (B, L) elementwise L2 magnitudes."""
    return np.sqrt(np.maximum(np.sum(a * a, axis=-1), 0.0))


def _lens(a: np.ndarray, lens) -> np.ndarray:
    if lens is None:
        return np.full(len(a), a.shape[1], np.int64)
    return np.asarray(lens, np.int64)


def _mask(a: np.ndarray, lens: np.ndarray) -> np.ndarray:
    return np.arange(a.shape[1])[None, :] < lens[:, None]


# -- tier 0: endpoint / global-mass bounds ------------------------------------


def _endpoint_costs(xs, ys, len_x, len_y):
    """Costs of the two mandatory alignment cells (1,1) and (lx,ly)."""
    xs, ys = _as3d(xs), _as3d(ys)
    lx = np.asarray(len_x, np.int64)
    ly = np.asarray(len_y, np.int64)
    r = np.arange(len(xs))
    c_first = _row_norm(xs[:, 0] - ys[:, 0])  # (B, d) -> (B,)
    c_last = _row_norm(xs[r, lx - 1] - ys[r, ly - 1])
    return c_first, c_last, lx, ly


def lb_dtw(xs, ys, len_x=None, len_y=None) -> np.ndarray:
    xs, ys = _as3d(xs), _as3d(ys)
    len_x = np.full(len(xs), xs.shape[1]) if len_x is None else len_x
    len_y = np.full(len(ys), ys.shape[1]) if len_y is None else len_y
    c0, ce, lx, ly = _endpoint_costs(xs, ys, len_x, len_y)
    return np.where(lx + ly > 2, c0 + ce, c0).astype(np.float32)


def lb_frechet(xs, ys, len_x=None, len_y=None) -> np.ndarray:
    xs, ys = _as3d(xs), _as3d(ys)
    len_x = np.full(len(xs), xs.shape[1]) if len_x is None else len_x
    len_y = np.full(len(ys), ys.shape[1]) if len_y is None else len_y
    c0, ce, _, _ = _endpoint_costs(xs, ys, len_x, len_y)
    return np.maximum(c0, ce).astype(np.float32)


def lb_erp(xs, ys, len_x=None, len_y=None, *, y_mass=None) -> np.ndarray:
    """| total gap mass of x − total gap mass of y | (Chen & Ng).

    ``y_mass`` optionally carries precomputed per-row candidate gap masses
    (``EnvelopeSet.mass`` gathered by candidate id) so the O(B*L) candidate
    row norms are paid once per database, not once per frontier round.
    """
    xs = _as3d(xs)
    lx = np.full(len(xs), xs.shape[1]) if len_x is None else np.asarray(len_x)
    sx = np.sum(np.where(_mask(xs, np.asarray(lx, np.int64)),
                         _row_norm(xs), 0.0), axis=1)
    if y_mass is not None:
        sy = np.asarray(y_mass, np.float32)
    else:
        ys = _as3d(ys)
        ly = np.full(len(ys), ys.shape[1]) if len_y is None \
            else np.asarray(len_y)
        sy = np.sum(np.where(_mask(ys, np.asarray(ly, np.int64)),
                             _row_norm(ys), 0.0), axis=1)
    return np.abs(sx - sy).astype(np.float32)


def lb_levenshtein(xs, ys, len_x=None, len_y=None) -> np.ndarray:
    xs, ys = np.asarray(xs), np.asarray(ys)
    lx = np.full(len(xs), xs.shape[1]) if len_x is None else np.asarray(len_x)
    ly = np.full(len(ys), ys.shape[1]) if len_y is None else np.asarray(len_y)
    return np.abs(lx - ly).astype(np.float32)


# -- precomputed per-window envelope statistics -------------------------------


@dataclasses.dataclass
class EnvelopeSet:
    """Per-window envelope statistics, one row per database window.

    ``lo``/``hi`` are the per-dimension envelope (axis-aligned bounding box
    over the window's valid positions — the LB_Keogh U/L envelope of an
    unconstrained warping band), ``mass`` the ERP total gap mass
    ``sum_j ||y_j||`` and ``cum`` its prefix sums (leading zero, so
    ``cum[i, m]`` is the gap mass of window i's first m elements)."""

    lo: np.ndarray      # (N, d)
    hi: np.ndarray      # (N, d)
    mass: np.ndarray    # (N,)
    cum: np.ndarray     # (N, L+1)
    lens: np.ndarray    # (N,)

    def take(self, idxs) -> "EnvelopeSet":
        idxs = np.asarray(idxs, np.int64)
        return EnvelopeSet(self.lo[idxs], self.hi[idxs], self.mass[idxs],
                           self.cum[idxs], self.lens[idxs])

    def extend(self, other: "EnvelopeSet") -> "EnvelopeSet":
        """Append rows in place (incremental ``FlatNet.append`` refresh)."""
        W = max(self.cum.shape[1], other.cum.shape[1])

        def padc(c):
            # prefix masses are monotone; edge-padding keeps cum[m] valid
            # (and m > len is masked out of every refinement anyway)
            return np.pad(c, ((0, 0), (0, W - c.shape[1])), mode="edge")

        self.lo = np.concatenate([self.lo, other.lo])
        self.hi = np.concatenate([self.hi, other.hi])
        self.mass = np.concatenate([self.mass, other.mass])
        self.cum = np.concatenate([padc(self.cum), padc(other.cum)])
        self.lens = np.concatenate([self.lens, other.lens])
        return self


def build_envelopes(data: np.ndarray, lens=None) -> EnvelopeSet:
    """ONE stacked vectorized pass over the whole window database."""
    a = _as3d(data)
    ln = _lens(a, lens)
    m = _mask(a, ln)[..., None]
    big = np.float32(3.4e38)
    lo = np.where(m, a, big).min(axis=1)
    hi = np.where(m, a, -big).max(axis=1)
    g = np.where(m[..., 0], _row_norm(a), 0.0)
    cum = np.concatenate(
        [np.zeros((len(a), 1), np.float32), np.cumsum(g, axis=1)],
        axis=1).astype(np.float32)
    return EnvelopeSet(lo.astype(np.float32), hi.astype(np.float32),
                       cum[np.arange(len(a)), ln].astype(np.float32),
                       cum, ln)


def _box_gap(xs3: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """(B, L, d) x (B, d) box -> (B, L) distance of each position to the box.

    For any point y inside the box, ``||x_i - y|| >= boxdist(x_i)``: per
    dimension the residual is at least the distance to the box interval,
    and the L2 norm is monotone per coordinate."""
    below = np.maximum(lo[:, None, :] - xs3, 0.0)
    above = np.maximum(xs3 - hi[:, None, :], 0.0)
    g = below + above  # at most one of the two is nonzero per dim
    return np.sqrt(np.maximum(np.sum(g * g, axis=-1), 0.0))


def _y_box(ys3, my, y_env: Optional[EnvelopeSet]):
    if y_env is not None:
        return y_env.lo, y_env.hi
    big = np.float32(3.4e38)
    m = my[..., None]
    return (np.where(m, ys3, big).min(axis=1),
            np.where(m, ys3, -big).max(axis=1))


# -- tier 1: envelope bounds --------------------------------------------------


def lb_dtw_envelope(xs, ys, len_x=None, len_y=None, *,
                    y_env: Optional[EnvelopeSet] = None) -> np.ndarray:
    """LB_Keogh-style envelope bound for (unconstrained) DTW.

    Soundness: DTW(x, y) is the sum of cell costs ``||x_i - y_j||`` along a
    monotone warping path that visits every index i of x at least once (and
    every j of y).  Hence

        DTW(x, y) >= sum_i  min_j ||x_i - y_j||
                  >= sum_i  boxdist(x_i, box(y)),

    since every y_j lies inside box(y) = [lo, hi]^d (the Keogh U/L envelope
    — with no warping-window constraint the envelope of every position is
    the whole sequence's box) and ``||x_i - y_j|| >= boxdist(x_i, box(y))``
    (:func:`_box_gap`).  The symmetric direction holds by the same argument
    with roles swapped, so the max of the two is a valid lower bound.
    """
    xs3, ys3 = _as3d(xs), _as3d(ys)
    lx, ly = _lens(xs3, len_x), _lens(ys3, len_y)
    mx, my = _mask(xs3, lx), _mask(ys3, ly)
    lo_y, hi_y = _y_box(ys3, my, y_env)
    d1 = np.sum(_box_gap(xs3, lo_y, hi_y) * mx, axis=1)
    big = np.float32(3.4e38)
    m = mx[..., None]
    lo_x = np.where(m, xs3, big).min(axis=1)
    hi_x = np.where(m, xs3, -big).max(axis=1)
    d2 = np.sum(_box_gap(ys3, lo_x, hi_x) * my, axis=1)
    return np.maximum(d1, d2).astype(np.float32)


def lb_frechet_envelope(xs, ys, len_x=None, len_y=None, *,
                        y_env: Optional[EnvelopeSet] = None) -> np.ndarray:
    """Envelope analogue for the discrete Frechet distance.

    Soundness: DFD(x, y) is the *max* of cell costs over a coupling that
    visits every index of both curves, so

        DFD(x, y) >= max_i min_j ||x_i - y_j|| >= max_i boxdist(x_i, box(y))

    (every y_j is inside box(y); see :func:`_box_gap`), and symmetrically
    for y against box(x); the max of the two directions is a valid bound.
    """
    xs3, ys3 = _as3d(xs), _as3d(ys)
    lx, ly = _lens(xs3, len_x), _lens(ys3, len_y)
    mx, my = _mask(xs3, lx), _mask(ys3, ly)
    lo_y, hi_y = _y_box(ys3, my, y_env)
    d1 = np.max(np.where(mx, _box_gap(xs3, lo_y, hi_y), 0.0), axis=1)
    big = np.float32(3.4e38)
    m = mx[..., None]
    lo_x = np.where(m, xs3, big).min(axis=1)
    hi_x = np.where(m, xs3, -big).max(axis=1)
    d2 = np.max(np.where(my, _box_gap(ys3, lo_x, hi_x), 0.0), axis=1)
    return np.maximum(d1, d2).astype(np.float32)


def lb_erp_envelope(xs, ys, len_x=None, len_y=None, *,
                    y_env: Optional[EnvelopeSet] = None) -> np.ndarray:
    """Envelope + per-prefix gap-mass refinement for ERP (gap g = 0).

    Two independent sound bounds, combined by max:

    1. *Element consumption*: an ERP edit script consumes each x_i exactly
       once — matched to some y_j (cost ``||x_i - y_j||``) or to a gap
       (cost ``||x_i||``).  Each element's one term is therefore at least
       ``min(||x_i||, min_j ||x_i - y_j||) >=
       min(||x_i||, boxdist(x_i, box(y)))``, and distinct x_i contribute
       distinct cost terms, so the sum over i is a lower bound.  The
       symmetric direction (each y_j consumed exactly once) holds the same
       way; a matched pair's cost is shared between the directions, so the
       two sums may NOT be added — their max is taken instead.

    2. *Per-prefix gap-mass refinement*: fix k = lx // 2.  Any edit script
       splits at the point where x's prefix x[:k] has been consumed,
       inducing a split y[:m] / y[m:] for some 0 <= m <= ly; its cost is
       the cost of a valid script for (x[:k], y[:m]) plus one for the
       suffixes, each of which is >= the gap-mass difference of its halves
       (bound 0 applied to the sub-script, i.e. the triangle inequality
       against the empty sequence).  Minimizing over the unknown m:

           ERP(x, y) >= min_m [ |G_x(k) - G_y(m)|
                                + |(T_x - G_x(k)) - (T_y - G_y(m))| ]

       with G the prefix gap masses and T the totals.  Each term of the
       min is >= |T_x - T_y| (triangle inequality on reals), so this
       refinement dominates the tier-0 global-mass bound — and is strictly
       tighter whenever no prefix mass G_y(m) falls between G_x(k) and
       T_y - (T_x - G_x(k)).

    ``y_env`` supplies precomputed candidate prefix masses
    (``EnvelopeSet.cum`` / ``mass``), so the refinement costs one gather
    plus O(B*L) elementwise work and no recomputed norms.
    """
    xs3, ys3 = _as3d(xs), _as3d(ys)
    lx, ly = _lens(xs3, len_x), _lens(ys3, len_y)
    mx, my = _mask(xs3, lx), _mask(ys3, ly)
    B = len(xs3)
    r = np.arange(B)

    gx = np.where(mx, _row_norm(xs3), 0.0)
    lo_y, hi_y = _y_box(ys3, my, y_env)
    cons_x = np.sum(np.minimum(gx, _box_gap(xs3, lo_y, hi_y)) * mx, axis=1)

    gy = np.where(my, _row_norm(ys3), 0.0)
    big = np.float32(3.4e38)
    m = mx[..., None]
    lo_x = np.where(m, xs3, big).min(axis=1)
    hi_x = np.where(m, xs3, -big).max(axis=1)
    cons_y = np.sum(np.minimum(gy, _box_gap(ys3, lo_x, hi_x)) * my, axis=1)

    # prefix refinement at k = lx // 2
    Gx = np.concatenate([np.zeros((B, 1), np.float32),
                         np.cumsum(gx, axis=1)], axis=1)
    Tx = Gx[r, lx]
    if y_env is not None:
        Gy, Ty = y_env.cum, y_env.mass
    else:
        Gy = np.concatenate([np.zeros((B, 1), np.float32),
                             np.cumsum(gy, axis=1)], axis=1)
        Ty = Gy[r, ly]
    a = Gx[r, lx // 2]
    b = Tx - a
    f = (np.abs(a[:, None] - Gy)
         + np.abs(b[:, None] - (Ty[:, None] - Gy)))
    valid_m = np.arange(Gy.shape[1])[None, :] <= ly[:, None]
    prefix = np.min(np.where(valid_m, f, np.inf), axis=1)

    return np.maximum(np.maximum(cons_x, cons_y),
                      prefix).astype(np.float32)


def lb_envelope_rows(name: str, xs, len_x, lo, hi, mass) -> np.ndarray:
    """One-direction envelope bound from PRECOMPUTED candidate envelopes.

    The gathered-statistics form used where candidate rows may not be
    materialized host-side (the fleet round engine and the device query
    path): only direction 1 of the two-sided envelope bounds above — query
    positions against each candidate's stored box — plus, for ERP, the
    tier-0 global-mass bound from the stored masses.  Each term is one of
    the sound bounds proved in the two-sided functions, so the result is a
    valid lower bound (just a looser one than the two-sided max).
    """
    xs3 = _as3d(xs)
    lx = _lens(xs3, len_x)
    mx = _mask(xs3, lx)
    lo = np.asarray(lo, np.float32)
    hi = np.asarray(hi, np.float32)
    bd = _box_gap(xs3, lo, hi)
    if name == "frechet":
        return np.max(np.where(mx, bd, 0.0), axis=1).astype(np.float32)
    if name == "dtw":
        return np.sum(bd * mx, axis=1).astype(np.float32)
    if name == "erp":
        gx = np.where(mx, _row_norm(xs3), 0.0)
        cons = np.sum(np.minimum(gx, bd) * mx, axis=1)
        gm = np.abs(gx.sum(axis=1) - np.asarray(mass, np.float32))
        return np.maximum(cons, gm).astype(np.float32)
    raise KeyError(f"no envelope bound for distance {name!r}")
