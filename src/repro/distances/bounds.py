"""Cheap, provable lower bounds for the batch engine's LB cascade.

Each function is vectorized numpy over row-paired batches and satisfies
``lb(x, y) <= delta(x, y)`` row-wise, so pruning a candidate whose bound
already exceeds eps can never change a range-query verdict — only skip its
exact O(l^2) DP.  Bounds cost O(B*l) (ERP) or O(B) (the rest), i.e. they
are free next to a single wavefront evaluation.

The bounds (Keogh-style endpoint/accumulation arguments):

* DTW — every warping path aligns (1,1) and (lx,ly); both cells carry
  nonnegative cost and are distinct whenever lx+ly > 2, so the sum of the
  two endpoint costs lower-bounds the path sum (LB_Kim first/last).
* DFD — the Frechet value is the *max* over an aligning path through the
  same two mandatory cells, so the larger endpoint cost is a bound.
* ERP — with gap element g = 0, ERP(x, y) >= | sum_i |x_i| - sum_j |y_j| |
  (Chen & Ng, VLDB'04): every edit script pays at least the difference of
  total gap masses.
* Levenshtein — at least |lx - ly| insertions/deletions are unavoidable.

Signature: ``(xs, ys, len_x, len_y) -> (B,)`` with ``xs: (B, Lx[, d])``,
``ys: (B, Ly[, d])`` and integer length vectors (rows may be padded).
"""

from __future__ import annotations

import numpy as np


def _as3d(a: np.ndarray) -> np.ndarray:
    a = np.asarray(a, np.float32)
    return a[..., None] if a.ndim == 2 else a


def _row_norm(a: np.ndarray) -> np.ndarray:
    """(B, L, d) -> (B, L) elementwise L2 magnitudes."""
    return np.sqrt(np.maximum(np.sum(a * a, axis=-1), 0.0))


def _endpoint_costs(xs, ys, len_x, len_y):
    """Costs of the two mandatory alignment cells (1,1) and (lx,ly)."""
    xs, ys = _as3d(xs), _as3d(ys)
    lx = np.asarray(len_x, np.int64)
    ly = np.asarray(len_y, np.int64)
    r = np.arange(len(xs))
    c_first = _row_norm(xs[:, 0] - ys[:, 0])  # (B, d) -> (B,)
    c_last = _row_norm(xs[r, lx - 1] - ys[r, ly - 1])
    return c_first, c_last, lx, ly


def lb_dtw(xs, ys, len_x=None, len_y=None) -> np.ndarray:
    xs, ys = _as3d(xs), _as3d(ys)
    len_x = np.full(len(xs), xs.shape[1]) if len_x is None else len_x
    len_y = np.full(len(ys), ys.shape[1]) if len_y is None else len_y
    c0, ce, lx, ly = _endpoint_costs(xs, ys, len_x, len_y)
    return np.where(lx + ly > 2, c0 + ce, c0).astype(np.float32)


def lb_frechet(xs, ys, len_x=None, len_y=None) -> np.ndarray:
    xs, ys = _as3d(xs), _as3d(ys)
    len_x = np.full(len(xs), xs.shape[1]) if len_x is None else len_x
    len_y = np.full(len(ys), ys.shape[1]) if len_y is None else len_y
    c0, ce, _, _ = _endpoint_costs(xs, ys, len_x, len_y)
    return np.maximum(c0, ce).astype(np.float32)


def lb_erp(xs, ys, len_x=None, len_y=None) -> np.ndarray:
    xs, ys = _as3d(xs), _as3d(ys)
    lx = np.full(len(xs), xs.shape[1]) if len_x is None else np.asarray(len_x)
    ly = np.full(len(ys), ys.shape[1]) if len_y is None else np.asarray(len_y)
    gx = _row_norm(xs)
    gy = _row_norm(ys)
    mx = np.arange(xs.shape[1])[None, :] < lx[:, None]
    my = np.arange(ys.shape[1])[None, :] < ly[:, None]
    sx = np.sum(np.where(mx, gx, 0.0), axis=1)
    sy = np.sum(np.where(my, gy, 0.0), axis=1)
    return np.abs(sx - sy).astype(np.float32)


def lb_levenshtein(xs, ys, len_x=None, len_y=None) -> np.ndarray:
    xs, ys = np.asarray(xs), np.asarray(ys)
    lx = np.full(len(xs), xs.shape[1]) if len_x is None else np.asarray(len_x)
    ly = np.full(len(ys), ys.shape[1]) if len_y is None else np.asarray(len_y)
    return np.abs(lx - ly).astype(np.float32)
