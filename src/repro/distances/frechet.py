"""Discrete Frechet distance (Eiter & Mannila 1994).

Metric and consistent (paper §4): the max-of-couplings alignment distance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distances import base, bounds
from repro.distances._wavefront import (
    BIG, default_lengths, l2_cost, matrixify, wavefront_dp)


def _combine(c, c_du, c_dl, dd, du, dl):
    return jnp.maximum(c, jnp.minimum(dd, jnp.minimum(du, dl)))


@jax.jit
def frechet_batch(xs, ys, len_x=None, len_y=None):
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if xs.ndim == 2:
        xs, ys = xs[..., None], ys[..., None]
    B, L = xs.shape[0], xs.shape[1]
    lx = default_lengths(xs, len_x)
    ly = default_lengths(ys, len_y)
    cost = l2_cost(xs, ys)
    border = jnp.full((B, L + 1), BIG, jnp.float32).at[:, 0].set(0.0)
    return wavefront_dp(cost, _combine, border, border, lx, ly)


frechet = base.register(base.Distance(
    name="frechet",
    batch=frechet_batch,
    matrix=matrixify(frechet_batch),
    metric=True,
    consistent=True,
    string=False,
    variable_length=True,
    doc="Discrete Frechet distance (DFD); metric",
    lower_bound=bounds.lb_frechet,
    envelope_bound=bounds.lb_frechet_envelope,
))
