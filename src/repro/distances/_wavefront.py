"""Anti-diagonal wavefront evaluation of alignment DPs (DTW/ERP/DFD/Levenshtein).

The four alignment distances used by the paper share one dynamic program over
an (Lx+1) x (Ly+1) table D, where D[i, j] relates prefixes x[:i] and y[:j].
They differ only in

* the border values D[i, 0], D[0, j],
* the cell ``combine`` rule.

A CPU implementation walks the table row-major; that serialises every cell.
On TPU we sweep **anti-diagonals**: diagonal k = i + j depends only on
diagonals k-1 and k-2, so each of the Lx+Ly steps is one fully vectorised
(B, Lx+1) min/add over the whole batch of DP problems — which is exactly the
shape of work the VPU wants.  The same schedule is implemented as a Pallas
VMEM kernel in ``repro.kernels.wavefront``; this module is the pure-jnp
engine (and the oracle the kernel is tested against).

Variable lengths are supported by padding to a common (Lx, Ly) and reading
the answer off diagonal len_x + len_y at position len_x.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

BIG = jnp.float32(3.4e37)  # quasi-infinity that survives adds without NaN


def _diag_gather(mat: jnp.ndarray, k, ii: jnp.ndarray) -> jnp.ndarray:
    """Gather mat[b, i-1, k-i-1] for each diagonal position i in ``ii``.

    Entries falling outside the Lx x Ly cost tile are returned as 0 (they are
    masked out of the DP by the border/validity logic).
    """
    B, Lx, Ly = mat.shape
    ci = ii - 1
    cj = k - ii - 1
    valid = (ci >= 0) & (cj >= 0) & (ci < Lx) & (cj < Ly)
    flat = jnp.clip(ci, 0, Lx - 1) * Ly + jnp.clip(cj, 0, Ly - 1)
    out = jnp.take(mat.reshape(B, Lx * Ly), flat, axis=1)
    return jnp.where(valid[None, :], out, 0.0)


def _shift_right(v: jnp.ndarray) -> jnp.ndarray:
    """v[i] -> v[i-1], injecting +inf at i = 0."""
    return jnp.concatenate([jnp.full_like(v[:, :1], BIG), v[:, :-1]], axis=1)


def wavefront_dp(
    cost: jnp.ndarray,
    combine: Callable,
    border_col: jnp.ndarray,
    border_row: jnp.ndarray,
    len_x: jnp.ndarray,
    len_y: jnp.ndarray,
    gap_x: Optional[jnp.ndarray] = None,
    gap_y: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Run the generic wavefront DP.

    Args:
      cost:       (B, Lx, Ly) elementwise cost tile c(x_i, y_j).
      combine:    f(c, c_du, c_dl, dd, du, dl) -> new cell value, where
                  dd = D[i-1,j-1], du = D[i-1,j], dl = D[i,j-1].
      border_col: (B, Lx+1) with border_col[:, i] = D[i, 0].
      border_row: (B, Ly+1) with border_row[:, j] = D[0, j].
      len_x/len_y: (B,) int actual lengths (len_x <= Lx, len_y <= Ly).
      gap_x:      (B, Lx) optional per-element gap cost for the du move (ERP).
      gap_y:      (B, Ly) optional per-element gap cost for the dl move (ERP).

    Returns:
      (B,) final D[len_x, len_y] per batch element.
    """
    B, Lx, Ly = cost.shape
    ii = jnp.arange(Lx + 1)
    target_k = len_x + len_y  # diagonal holding the answer

    diag0 = jnp.full((B, Lx + 1), BIG, cost.dtype).at[:, 0].set(border_col[:, 0])

    # Answer for degenerate len_x = len_y = 0 lives on diagonal 0.
    res0 = jnp.where(target_k == 0, diag0[:, 0], BIG)

    # Gap cost for the du move is indexed by diagonal position only:
    # pos i uses gap_x[i-1] (independent of k).
    gxv = None
    if gap_x is not None:
        gxv = jnp.concatenate([jnp.zeros((B, 1), cost.dtype), gap_x], axis=1)

    def step(carry, k):
        d1, d2, res = carry  # diagonals k-1 and k-2
        c = _diag_gather(cost, k, ii)
        dd = _shift_right(d2)
        du = _shift_right(d1)
        dl = d1
        c_du = gxv if gxv is not None else None
        c_dl = None
        if gap_y is not None:
            # gap_y gathered along the diagonal: position i -> gap_y[k-i-1]
            cj = k - ii - 1
            validj = (cj >= 0) & (cj < Ly)
            c_dl = jnp.take(gap_y, jnp.clip(cj, 0, Ly - 1), axis=1)
            c_dl = jnp.where(validj[None, :], c_dl, 0.0)
        new = combine(c, c_du, c_dl, dd, du, dl)
        # Clamp: sums involving the BIG quasi-infinity sentinel (or extreme
        # gap-mass borders) must stay at BIG, not overflow to float32
        # inf/NaN — BIG's ordering against real values is what masks cells.
        new = jnp.minimum(new, BIG)
        # Borders: i = k is column j = 0; i = 0 is row j = k.
        new = jnp.where((ii == k)[None, :] & (k <= Lx),
                        border_col[:, jnp.minimum(k, Lx)][:, None], new)
        new = jnp.where((ii == 0)[None, :],
                        jnp.where(k <= Ly,
                                  border_row[:, jnp.minimum(k, Ly)][:, None],
                                  BIG),
                        new)
        # Mask positions outside the valid band i in [max(0, k-Ly), min(k, Lx)].
        invalid = (ii > k) | (ii < k - Ly)
        new = jnp.where(invalid[None, :], BIG, new)
        # Record the answer when this diagonal holds it.
        val = jnp.take_along_axis(new, len_x[:, None], axis=1)[:, 0]
        res = jnp.where(target_k == k, val, res)
        return (new, d1, res), None

    dinit = jnp.full((B, Lx + 1), BIG, cost.dtype)
    (d_last, _, res), _ = jax.lax.scan(
        step, (diag0, dinit, res0), jnp.arange(1, Lx + Ly + 1))
    return res


# ---------------------------------------------------------------------------
# Cost tiles
# ---------------------------------------------------------------------------

def l2_cost(xs: jnp.ndarray, ys: jnp.ndarray) -> jnp.ndarray:
    """(B,Lx,d),(B,Ly,d) -> (B,Lx,Ly) pairwise Euclidean element cost."""
    diff = xs[:, :, None, :] - ys[:, None, :, :]
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def neq_cost(xs: jnp.ndarray, ys: jnp.ndarray) -> jnp.ndarray:
    """(B,Lx),(B,Ly) int tokens -> (B,Lx,Ly) 0/1 substitution cost."""
    return (xs[:, :, None] != ys[:, None, :]).astype(jnp.float32)


def default_lengths(xs, len_x):
    B, L = xs.shape[0], xs.shape[1]
    if len_x is None:
        return jnp.full((B,), L, jnp.int32)
    return jnp.asarray(len_x, jnp.int32)


def matrixify(batch_fn):
    """Lift a paired batch distance to an all-pairs (M, N) matrix."""

    def matrix(xs, ys, len_x=None, len_y=None):
        xs, ys = jnp.asarray(xs), jnp.asarray(ys)
        M, N = xs.shape[0], ys.shape[0]
        lx = default_lengths(xs, len_x)
        ly = default_lengths(ys, len_y)
        xs_b = jnp.repeat(xs, N, axis=0)
        ys_b = jnp.tile(ys, (M,) + (1,) * (ys.ndim - 1))
        lx_b = jnp.repeat(lx, N, axis=0)
        ly_b = jnp.tile(ly, (M,))
        return batch_fn(xs_b, ys_b, lx_b, ly_b).reshape(M, N)

    return matrix
