"""ERP — Edit distance with Real Penalty (Chen & Ng, VLDB'04).

Metric AND consistent: the paper's recommended time-series distance for the
indexed path (§5).  Gap element g defaults to the origin.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distances import base, bounds
from repro.distances._wavefront import (
    default_lengths, l2_cost, matrixify, wavefront_dp)


def _combine(c, c_du, c_dl, dd, du, dl):
    return jnp.minimum(dd + c, jnp.minimum(du + c_du, dl + c_dl))


@jax.jit
def erp_batch(xs, ys, len_x=None, len_y=None):
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if xs.ndim == 2:
        xs, ys = xs[..., None], ys[..., None]
    B, L = xs.shape[0], xs.shape[1]
    lx = default_lengths(xs, len_x)
    ly = default_lengths(ys, len_y)
    cost = l2_cost(xs, ys)
    # Gap cost: distance of each element to the gap element g = 0.
    gap_x = jnp.sqrt(jnp.maximum(jnp.sum(xs * xs, axis=-1), 0.0))  # (B, L)
    gap_y = jnp.sqrt(jnp.maximum(jnp.sum(ys * ys, axis=-1), 0.0))
    # Mask padding out of the cumulative borders.
    posl = jnp.arange(L)[None, :]
    gap_x = jnp.where(posl < lx[:, None], gap_x, 0.0)
    gap_y = jnp.where(posl < ly[:, None], gap_y, 0.0)
    zero = jnp.zeros((B, 1), jnp.float32)
    border_col = jnp.concatenate([zero, jnp.cumsum(gap_x, axis=1)], axis=1)
    border_row = jnp.concatenate([zero, jnp.cumsum(gap_y, axis=1)], axis=1)
    return wavefront_dp(cost, _combine, border_col, border_row, lx, ly,
                        gap_x=gap_x, gap_y=gap_y)


erp = base.register(base.Distance(
    name="erp",
    batch=erp_batch,
    matrix=matrixify(erp_batch),
    metric=True,
    consistent=True,
    string=False,
    variable_length=True,
    doc="Edit distance with Real Penalty; gap element g = 0; metric",
    lower_bound=bounds.lb_erp,
    envelope_bound=bounds.lb_erp_envelope,
))
