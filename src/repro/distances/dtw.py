"""Dynamic Time Warping.

Consistent (paper §4) but NOT metric (paper §3.3/§5): usable with the
segmentation filter + linear scan, rejected by the metric indexes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distances import base, bounds
from repro.distances._wavefront import (
    BIG, default_lengths, l2_cost, matrixify, wavefront_dp)


def _combine(c, c_du, c_dl, dd, du, dl):
    return c + jnp.minimum(dd, jnp.minimum(du, dl))


@functools.partial(jax.jit, static_argnames=())
def dtw_batch(xs, ys, len_x=None, len_y=None):
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if xs.ndim == 2:  # scalar series -> (B, L, 1)
        xs, ys = xs[..., None], ys[..., None]
    B, L = xs.shape[0], xs.shape[1]
    lx = default_lengths(xs, len_x)
    ly = default_lengths(ys, len_y)
    cost = l2_cost(xs, ys)
    border = jnp.full((B, L + 1), BIG, jnp.float32).at[:, 0].set(0.0)
    return wavefront_dp(cost, _combine, border, border, lx, ly)


dtw = base.register(base.Distance(
    name="dtw",
    batch=dtw_batch,
    matrix=matrixify(dtw_batch),
    metric=False,
    consistent=True,
    string=False,
    variable_length=True,
    doc="Dynamic Time Warping; element cost = Euclidean",
    lower_bound=bounds.lb_dtw,
    envelope_bound=bounds.lb_dtw_envelope,
))
