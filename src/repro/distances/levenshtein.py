"""Levenshtein (edit) distance over integer token sequences.

Metric and consistent (paper §4): the paper's string-database distance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distances import base, bounds
from repro.distances._wavefront import (
    default_lengths, matrixify, neq_cost, wavefront_dp)


def _combine(c, c_du, c_dl, dd, du, dl):
    return jnp.minimum(dd + c, jnp.minimum(du + 1.0, dl + 1.0))


@jax.jit
def levenshtein_batch(xs, ys, len_x=None, len_y=None):
    xs = jnp.asarray(xs, jnp.int32)
    ys = jnp.asarray(ys, jnp.int32)
    B, L = xs.shape
    lx = default_lengths(xs, len_x)
    ly = default_lengths(ys, len_y)
    cost = neq_cost(xs, ys)
    ar = jnp.arange(L + 1, dtype=jnp.float32)[None, :]
    border = jnp.broadcast_to(ar, (B, L + 1))
    return wavefront_dp(cost, _combine, border, border, lx, ly)


levenshtein = base.register(base.Distance(
    name="levenshtein",
    batch=levenshtein_batch,
    matrix=matrixify(levenshtein_batch),
    metric=True,
    consistent=True,
    string=True,
    variable_length=True,
    doc="Levenshtein / edit distance over token ids; metric",
    lower_bound=bounds.lb_levenshtein,
))
