"""accounting-soundness pass — every distance is counted, padding never is.

The paper's pruning-ratio currency (``evals_frac`` etc.) is only meaningful
if every evaluated distance flows through an accounting point:
``CountedDistance`` (the counter buckets), ``DispatchStats`` (the packed
dispatcher tallies rows/dispatches/LB tiers), or a shard's
``device_stats``.  A call site that grabs a :class:`KernelSpec` and calls
``.batch``/``.device_call`` raw — or reduces a padded array without
slicing back to the true row count — silently corrupts the counts the CI
baselines gate.

Rules
-----
``acct-raw-kernel-call``
    ``.device_call(...)``/``.batch(...)`` on a spec obtained from the
    kernel registry (or a raw ``np_backend.batch_for`` callable) outside
    the accounting-owner modules: ``core/counter.py`` (the counter),
    ``kernels/dispatch.py`` (tallies ``DispatchStats``),
    ``kernels/registry.py`` (the substrate itself),
    ``core/distributed.py`` (returns device stats to the elastic layer),
    and ``distances/np_backend.py`` (the oracle backend's own internals).
``acct-padded-slice``
    A reduction (``.sum()``/``np.sum``/``count_nonzero``/``.mean()``) over
    a name bound from a padding helper (``pad_ragged_rows``/``_pad_rows``/
    ``_pad_batch``/``np.pad``) with no interposed slice: the padding rows
    are counted as if they were data.  Slice with the ``PackedMeta`` row
    count (or the pre-pad batch size) first.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.core import (Finding, Module, call_terminal, dotted,
                                 module_functions, register)

#: modules that own an accounting point (see module docstring)
ACCT_OWNERS = ("core/counter.py", "kernels/dispatch.py",
               "kernels/registry.py", "core/distributed.py",
               "distances/np_backend.py")

#: registry getters whose result is a KernelSpec (or raw batch callable)
SPEC_GETTERS = {"get", "get_envelope", "spec_for_mode", "batch_for"}
RAW_CALLS = {"device_call", "batch"}

PAD_HELPERS = {"pad_ragged_rows", "_pad_rows", "_pad_batch", "pad"}
REDUCTIONS = {"sum", "mean", "count_nonzero", "nonzero", "prod"}


def _is_spec_getter(call: ast.Call) -> bool:
    name = call_terminal(call)
    if name not in SPEC_GETTERS:
        return False
    if name == "get":
        # disambiguate from dict.get / the models config registry: require
        # a receiver chain mentioning a kernel registry
        root = dotted(call.func) or ""
        return "registry" in root.split(".")[0] or \
            root.startswith("kernel_registry")
    return True


@register("accounting")
def check(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    if not mod.rel.endswith(ACCT_OWNERS):
        out.extend(_raw_kernel_calls(mod))
    out.extend(_padded_reductions(mod))
    return out


def _raw_kernel_calls(mod: Module) -> List[Finding]:
    # the module tree and each def are scanned with their own local spec
    # bindings; a call visible from both scans is reported once
    found: List[Finding] = []
    reported: set = set()
    for func in [mod.tree] + module_functions(mod.tree):
        specs: Set[str] = set()
        for node in ast.walk(func):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not func:
                continue
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    _is_spec_getter(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        specs.add(t.id)
        for node in ast.walk(func):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            flagged = None
            if isinstance(f, ast.Attribute) and f.attr in RAW_CALLS:
                if isinstance(f.value, ast.Name) and f.value.id in specs:
                    flagged = f"{f.value.id}.{f.attr}"
                elif isinstance(f.value, ast.Call) and \
                        _is_spec_getter(f.value):
                    flagged = f"<registry getter>.{f.attr}"
            elif isinstance(f, ast.Name) and f.id in specs:
                flagged = f.id
            if flagged and id(node) not in reported:
                reported.add(id(node))
                found.append(Finding(
                    mod.rel, node.lineno, "acct-raw-kernel-call",
                    f"raw kernel call '{flagged}(...)' bypasses "
                    "CountedDistance / DispatchStats accounting; route "
                    "through the counter or the packed dispatcher"))
    return found


def _padded_reductions(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    for func in module_functions(mod.tree):
        padded: Dict[str, int] = {}
        sliced: Set[str] = set()
        for node in ast.walk(func):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    call_terminal(node.value) in PAD_HELPERS:
                for t in node.targets:
                    # pad helpers return the padded array either bare or
                    # first in a (padded, lens) tuple
                    if isinstance(t, ast.Tuple) and t.elts:
                        t = t.elts[0]
                    if isinstance(t, ast.Name):
                        padded[t.id] = node.lineno
            if isinstance(node, ast.Subscript) and \
                    isinstance(node.value, ast.Name):
                sliced.add(node.value.id)
        if not padded:
            continue
        for node in ast.walk(func):
            if not isinstance(node, ast.Call):
                continue
            name = call_terminal(node)
            if name not in REDUCTIONS:
                continue
            # receiver (x.sum()) or first arg (np.sum(x)) is a padded name
            cand = None
            if isinstance(node.func, ast.Attribute) and \
                    isinstance(node.func.value, ast.Name):
                cand = node.func.value.id
            elif node.args and isinstance(node.args[0], ast.Name):
                cand = node.args[0].id
            if cand in padded and cand not in sliced:
                out.append(Finding(
                    mod.rel, node.lineno, "acct-padded-slice",
                    f"reduction over padded array '{cand}' (padded at "
                    f"line {padded[cand]}) without slicing back to the "
                    "true row count: padding rows are being counted"))
    return out
