"""Substrate invariant linter — AST passes over ``src/repro``.

Five passes make the architecture rules of PRs 1-7 machine-checked (see
``docs/architecture.md`` § "Substrate invariants"):

=================  ========================================================
pass               invariant
=================  ========================================================
``dispatch``       no per-item device dispatch inside loops (PR 2/5/6)
``trace``          nothing breaks the one-compile-per-shape jit cache (PR 5)
``accounting``     every distance is counted; padding rows never are (PR 1/5)
``sentinel``       BIG quasi-infinity arithmetic is always clamped (PR 5)
``shims``          deprecation shims warn and document v0.2 removal (PR 4)
=================  ========================================================

CLI: ``python tools/lint.py [--format=json] [--root src/repro]``.
"""

from repro.analysis.core import (Finding, Module, pass_names,  # noqa: F401
                                 register, render_human, run, to_json)
