"""trace-safety pass — the static mirror of the ``STATS["traces"]==0`` gate.

The kernel registry compiles each shape class exactly once; the warm-sweep
benchmarks pin ``traces == 0``.  Everything that silently breaks that gate
at a call site — Python branching on traced values, host concretization,
``np.`` calls inside traced code, per-call ``jax.jit`` wrapping, unhashable
or loop-rebound static arguments — is visible in the AST.

A *jit region* is: a function decorated with ``jax.jit`` (bare or via
``partial(jax.jit, static_argnums=...)``), a function or lambda passed to
``jax.jit(...)``, a Pallas kernel body (>= 2 parameters ending in
``_ref``), a loop body handed to ``lax.scan`` / ``lax.fori_loop`` /
``lax.while_loop`` (the compiled ``exec="scan"`` wavefront enters the
registry's jit cache exactly this way — its per-diagonal body is traced
even though nothing around it is decorated), or a module-local function
called from any of those (one hop).

Rules
-----
``trace-host-branch``
    ``if``/``while``/ternary on a traced value inside a jit region.
    Static-safe tests are exempt: shape attrs (``.shape``/``.ndim``/
    ``.dtype``/``.size``), ``len()``/``isinstance()``, ``is None`` checks,
    and parameters declared static via ``static_argnums``/``-names``.
``trace-concretize``
    ``float()``/``int()``/``bool()`` over a traced value, or ``.item()``,
    inside a jit region — forces a host sync and breaks tracing.
``trace-numpy-call``
    ``np.``/``numpy.`` call inside a jit region (silently constant-folds
    the traced value or raises at trace time) — use ``jnp``.
``trace-fresh-jit``
    ``jax.jit(...)`` bound to a plain local inside a function: a fresh
    traced callable per call.  Sanctioned cache patterns are exempt — a
    subscript store (``_CACHE[key] = fn``, the registry pattern) or an
    attribute store (``self.step_fn = jax.jit(...)``, construct-once).
``trace-static-unhashable``
    A list/set/dict literal passed in a static-argument position of a
    locally-resolvable jitted callable (TypeError at call time).
``trace-static-rebound``
    A static-position argument rebound inside the very loop that calls the
    jitted callable: every iteration is a recompile.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, Module, call_terminal, dotted,
                                 is_jax_jit, module_functions, register)

SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
STATIC_SAFE_CALLS = {"len", "isinstance", "getattr", "hasattr"}


def _jit_decorator_statics(func) -> Optional[Tuple[Set[int], Set[str]]]:
    """(static positions, static names) if ``func`` is jit-decorated."""
    for dec in getattr(func, "decorator_list", []):
        if is_jax_jit(dec):
            return set(), set()
        if isinstance(dec, ast.Call):
            target = dec.func
            if is_jax_jit(target):
                return _statics_from_keywords(dec)
            if dotted(target) in ("partial", "functools.partial") and \
                    dec.args and is_jax_jit(dec.args[0]):
                return _statics_from_keywords(dec)
    return None


def _statics_from_keywords(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.add(n.value)
        elif kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
    return nums, names


def _params(func) -> List[str]:
    a = func.args
    return [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]


def _is_pallas_kernel(func) -> bool:
    params = _params(func)
    return sum(p.endswith("_ref") for p in params) >= 2


def _collect_regions(mod: Module):
    """[(func node, static positions, static names)] plus one-hop callees."""
    regions = []
    by_name: Dict[str, ast.AST] = {
        f.name: f for f in module_functions(mod.tree)}

    for func in module_functions(mod.tree):
        statics = _jit_decorator_statics(func)
        if statics is not None:
            regions.append((func, *statics))
        elif _is_pallas_kernel(func):
            regions.append((func, set(), set()))

    # functions/lambdas passed to jax.jit(...) at any nesting level
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and is_jax_jit(node.func) and node.args:
            arg = node.args[0]
            target = None
            if isinstance(arg, ast.Lambda):
                target = arg
            elif isinstance(arg, ast.Name) and arg.id in by_name:
                target = by_name[arg.id]
            else:
                # jax.jit(local_def) where local_def is nested: resolve by
                # scanning the enclosing scopes' defs
                if isinstance(arg, ast.Name):
                    for f in module_functions(mod.tree):
                        if f.name == arg.id:
                            target = f
                            break
            if target is not None and \
                    not any(target is r[0] for r in regions):
                regions.append((target, *_statics_from_keywords(node)))

    # loop bodies handed to lax.scan / fori_loop / while_loop are traced
    # regions with no static params — the scan-mode wavefront
    # (kernels/wavefront.wavefront_scan) reaches the registry jit cache
    # through exactly this shape, with zero jit decorators in sight
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and
                call_terminal(node) in ("scan", "fori_loop", "while_loop")):
            continue
        root = dotted(node.func)
        if not root or root.split(".")[0] not in ("lax", "jax"):
            continue
        for arg in node.args:
            target = None
            if isinstance(arg, ast.Lambda):
                target = arg
            elif isinstance(arg, ast.Name) and arg.id in by_name:
                target = by_name[arg.id]
            if target is not None and \
                    not any(target is r[0] for r in regions):
                regions.append((target, set(), set()))

    # one-hop reachability: module-local defs called from a region body.
    # Params fed an argument the CALLER does not itself trace (closure
    # config objects, static metadata riding through a scan body) stay
    # static in the callee — branch-on-config is not branch-on-traced.
    for func, snums, snames in list(regions):
        caller_traced = _traced_params(func, snums, snames)
        for node in ast.walk(func):
            if not (isinstance(node, ast.Call) and
                    isinstance(node.func, ast.Name)):
                continue
            callee = by_name.get(node.func.id)
            if callee is None or any(callee is r[0] for r in regions):
                continue
            params = _params(callee)
            inherited: Set[str] = set()
            for i, a in enumerate(node.args):
                if i < len(params) and isinstance(a, ast.Name) and \
                        a.id not in caller_traced:
                    inherited.add(params[i])
            for kw in node.keywords:
                if kw.arg and isinstance(kw.value, ast.Name) and \
                        kw.value.id not in caller_traced:
                    inherited.add(kw.arg)
            regions.append((callee, set(), inherited))
    return regions


def _traced_params(func, static_nums: Set[int],
                   static_names: Set[str]) -> Set[str]:
    params = _params(func)
    traced = set(params) - static_names - {"self", "cls"}
    for i in static_nums:
        if i < len(params):
            traced.discard(params[i])
    return traced


def _name_is_static_safe(mod: Module, name: ast.Name, test: ast.AST) -> bool:
    """Traced-name reference that is still trace-safe in a branch test."""
    node: ast.AST = name
    for anc in mod.ancestors(name):
        if isinstance(anc, ast.Attribute) and anc.attr in SHAPE_ATTRS:
            return True
        if isinstance(anc, ast.Call) and \
                call_terminal(anc) in STATIC_SAFE_CALLS:
            return True
        if isinstance(anc, ast.Compare) and \
                all(isinstance(op, (ast.Is, ast.IsNot)) for op in anc.ops):
            return True
        if anc is test:
            break
        node = anc
    return False


@register("trace")
def check(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    regions = _collect_regions(mod)
    region_funcs = [r[0] for r in regions]

    for func, static_nums, static_names in regions:
        traced = _traced_params(func, static_nums, static_names)
        # include nested defs' params (fori_loop bodies etc.); nested defs
        # that are themselves separate regions get their own scan
        for inner in ast.walk(func):
            if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and inner is not func \
                    and not any(inner is f for f in region_funcs):
                traced |= set(_params(inner)) - {"self", "cls"}

        for node in ast.walk(func):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                test = node.test
                for n in ast.walk(test):
                    if isinstance(n, ast.Name) and n.id in traced and \
                            not _name_is_static_safe(mod, n, test):
                        out.append(Finding(
                            mod.rel, test.lineno, "trace-host-branch",
                            f"Python branch on traced value '{n.id}' "
                            "inside a jit region; use lax.cond/select or "
                            "declare the argument static"))
                        break
            elif isinstance(node, ast.Call):
                name = call_terminal(node)
                if isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "int", "bool"):
                    if any(isinstance(n, ast.Name) and n.id in traced
                           for a in node.args for n in ast.walk(a)):
                        out.append(Finding(
                            mod.rel, node.lineno, "trace-concretize",
                            f"'{node.func.id}()' concretizes a traced "
                            "value inside a jit region (host sync / "
                            "TracerError)"))
                elif name == "item" and isinstance(node.func, ast.Attribute):
                    out.append(Finding(
                        mod.rel, node.lineno, "trace-concretize",
                        "'.item()' concretizes a traced value inside a "
                        "jit region"))
                elif isinstance(node.func, ast.Attribute):
                    root = dotted(node.func)
                    if root and root.split(".")[0] in ("np", "numpy"):
                        out.append(Finding(
                            mod.rel, node.lineno, "trace-numpy-call",
                            f"'{root}(...)' inside a jit region constant-"
                            "folds or fails under tracing; use jnp"))

    out.extend(_check_fresh_jit(mod))
    out.extend(_check_static_args(mod))
    return out


def _check_fresh_jit(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    for func in module_functions(mod.tree):
        # names stored through the sanctioned cache patterns in this func
        cached: Set[str] = set()
        for node in ast.walk(func):
            if isinstance(node, ast.Assign):
                stored = any(isinstance(t, (ast.Subscript, ast.Attribute))
                             for t in node.targets)
                if stored:
                    for n in ast.walk(node.value):
                        if isinstance(n, ast.Name):
                            cached.add(n.id)
                    if isinstance(node.value, ast.Call) and \
                            is_jax_jit(node.value.func):
                        cached.add("<inline>")
        for node in ast.walk(func):
            if not (isinstance(node, ast.Call) and is_jax_jit(node.func)):
                continue
            parent = mod.parents().get(node)
            if isinstance(parent, ast.Attribute) and \
                    parent.attr in ("lower", "trace"):
                continue              # AOT introspection: jit(fn).lower(..)
            if isinstance(parent, ast.Assign):
                targets = parent.targets
                if any(isinstance(t, (ast.Subscript, ast.Attribute))
                       for t in targets):
                    continue          # CACHE[key] = / self.fn = : sanctioned
                if any(isinstance(t, ast.Name) and t.id in cached
                       for t in targets):
                    continue          # fn = jax.jit(..); CACHE[key] = fn
                names = {t.id for t in targets if isinstance(t, ast.Name)}
                if names and _only_aot_uses(mod, func, names):
                    continue          # fn = jax.jit(..); fn.lower(...): AOT
            out.append(Finding(
                mod.rel, node.lineno, "trace-fresh-jit",
                "jax.jit(...) creates a fresh traced callable per call; "
                "hoist it or store it in a module-level cache "
                "(see kernels/registry.KernelSpec._cached)"))
    return out


def _only_aot_uses(mod: Module, func: ast.AST, names: Set[str]) -> bool:
    """True when every read of ``names`` in ``func`` is an AOT access
    (``fn.lower(...)``/``.trace``/``.compile``) — the callable is never
    dispatched, so there is no per-call retrace to leak."""
    uses = [n for n in ast.walk(func)
            if isinstance(n, ast.Name) and n.id in names
            and isinstance(n.ctx, ast.Load)]
    if not uses:
        return False
    parents = mod.parents()
    for n in uses:
        p = parents.get(n)
        if not (isinstance(p, ast.Attribute)
                and p.attr in ("lower", "trace", "compile")):
            return False
    return True


def _jitted_static_positions(mod: Module) -> Dict[str, Set[int]]:
    """Jitted module-level defs -> static arg positions, plus defs that
    forward their own params into those positions (one hop)."""
    statics: Dict[str, Set[int]] = {}
    for func in module_functions(mod.tree):
        got = _jit_decorator_statics(func)
        if got and got[0]:
            statics[func.name] = got[0]
    # one-hop forwarding: def run(cap): return _jitted(..., cap, ...)
    for func in module_functions(mod.tree):
        if func.name in statics:
            continue
        params = _params(func)
        fwd: Set[int] = set()
        for node in ast.walk(func):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id in statics:
                for pos in statics[node.func.id]:
                    if pos < len(node.args):
                        a = node.args[pos]
                        if isinstance(a, ast.Name) and a.id in params:
                            fwd.add(params.index(a.id))
        if fwd:
            statics[func.name] = fwd
    return statics


def _check_static_args(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    statics = _jitted_static_positions(mod)
    if not statics:
        return out
    for func in module_functions(mod.tree):
        for loop in ast.walk(func):
            if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                continue
            rebound = set()
            if isinstance(loop, (ast.For, ast.AsyncFor)):
                rebound |= {n.id for n in ast.walk(loop.target)
                            if isinstance(n, ast.Name)}
            for stmt in loop.body + loop.orelse:
                for n in ast.walk(stmt):
                    if isinstance(n, ast.Name) and \
                            isinstance(n.ctx, ast.Store):
                        rebound.add(n.id)
            for stmt in loop.body + loop.orelse:
                for node in ast.walk(stmt):
                    if not (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Name)
                            and node.func.id in statics):
                        continue
                    for pos in statics[node.func.id]:
                        if pos >= len(node.args):
                            continue
                        a = node.args[pos]
                        if isinstance(a, ast.Name) and a.id in rebound:
                            out.append(Finding(
                                mod.rel, node.lineno,
                                "trace-static-rebound",
                                f"static arg '{a.id}' of jitted "
                                f"'{node.func.id}' is rebound in this "
                                "loop: every iteration recompiles"))
        for node in ast.walk(func):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in statics):
                continue
            for pos in statics[node.func.id]:
                if pos < len(node.args) and isinstance(
                        node.args[pos], (ast.List, ast.Set, ast.Dict)):
                    out.append(Finding(
                        mod.rel, node.lineno, "trace-static-unhashable",
                        f"unhashable literal in static position {pos} of "
                        f"jitted '{node.func.id}' (TypeError at call "
                        "time); pass a tuple"))
    return out
