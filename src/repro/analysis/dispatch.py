"""dispatch-discipline pass — no per-item device dispatch inside loops.

The whole PR-5/6 substrate exists so that retrieval never pays one backend
dispatch per segment/candidate/shard: plans yield frontiers, the engine
merges them, and each merged round is ONE packed call.  A new call site
that loops ``Distance.batch`` / ``KernelSpec.device_call`` /
``dispatch.packed_batch`` / ``CountedDistance.eval_stacked`` (or a
per-query ``range_query``) inside a ``for``/``while`` body silently
reintroduces the antipattern — until a bench baseline catches the
dispatch-count rise.  This pass catches it at lint time.

Rules
-----
``dispatch-in-loop``
    A call whose terminal name is a dispatch entry point executes once per
    loop iteration, outside the whitelisted engine drivers
    (``core/batch_engine.py`` drives frontiers by contract;
    ``core/counter.py`` owns the backend dispatch itself).
``dispatch-jit-in-loop``
    A callable bound from ``jax.jit(...)`` in the enclosing function is
    invoked inside a loop body — the per-item-dispatch antipattern in its
    rawest form (and usually a fresh-trace leak too; see trace-safety).
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import (Finding, Module, call_terminal,
                                 calls_in_loops, is_jax_jit,
                                 module_functions, register)

#: terminal callable names that are device/batched dispatch entry points
DISPATCH_NAMES = {"batch", "device_call", "packed_batch", "packed_envelope",
                  "eval_stacked", "range_query"}

#: modules allowed to drive dispatch from loops: the batch engine IS the
#: loop the substrate sanctions (one packed dispatch per merged round), the
#: counter owns the backend call under it, and the serve engine's tick loop
#: drives the batch engine (one shared round per tick)
ENGINE_DRIVERS = ("core/batch_engine.py", "core/counter.py",
                  "serve/engine.py")


@register("dispatch")
def check(mod: Module) -> List[Finding]:
    if mod.rel.endswith(ENGINE_DRIVERS):
        return []
    out: List[Finding] = []
    for func in [mod.tree] + module_functions(mod.tree):
        jitted = _jit_bound_names(func)
        for call in calls_in_loops(func):
            name = call_terminal(call)
            if name in DISPATCH_NAMES:
                out.append(Finding(
                    mod.rel, call.lineno, "dispatch-in-loop",
                    f"'{name}(...)' runs once per loop iteration; batch "
                    "the items and dispatch once (engine round / packed "
                    "call), or drive through core/batch_engine"))
            elif (isinstance(call.func, ast.Name)
                  and call.func.id in jitted):
                out.append(Finding(
                    mod.rel, call.lineno, "dispatch-jit-in-loop",
                    f"jitted callable '{call.func.id}' is invoked per "
                    "loop iteration; stack the batch and call it once"))
    # module-level statements double as function bodies above via mod.tree;
    # dedupe (a call can appear under both the module walk and a def walk)
    seen = set()
    uniq = []
    for f in out:
        key = (f.line, f.rule, f.message)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq


def _jit_bound_names(func: ast.AST) -> set:
    """Local names assigned directly from ``jax.jit(...)`` in ``func``."""
    names = set()
    for node in ast.walk(func):
        if (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)
                and is_jax_jit(node.value.func)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names
