"""sentinel-overflow pass — arithmetic on the ``BIG`` quasi-infinity.

The wavefront DP uses ``BIG = 3.4e37`` as a quasi-infinite cell value.
Adding or multiplying it without an interposed clamp runs off to float32
``inf`` within a few combines (``BIG + BIG`` overflows), and ``inf - x``
then poisons the fused-ε certificate with NaNs.  PR 5 fixed this at
runtime with ``jnp.minimum(new, BIG)`` after every combine; this pass is
the static form of that fix.

Rule
----
``sentinel-unclamped-arith``
    ``+``/``*`` with a ``BIG``-bound operand, or ``sum``/``cumsum`` over
    one, anywhere in the statement that is not under a ``minimum``/
    ``clip``/``clamp``/``min`` call.  ``BIG``-bound means: the literal
    name imported from ``kernels.wavefront``, a direct alias assignment
    (``INF = BIG``), or an attribute access ending ``.BIG``.
"""

from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.core import (Finding, Module, call_terminal, register)

CLAMPS = {"minimum", "clip", "clamp", "min"}
SUMS = {"sum", "cumsum"}


def _big_names(mod: Module) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name == "BIG":
                    names.add(alias.asname or alias.name)
        elif isinstance(node, ast.Assign):
            v = node.value
            if (isinstance(v, ast.Name) and v.id in names | {"BIG"}) or \
                    (isinstance(v, ast.Attribute) and v.attr == "BIG"):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
            elif isinstance(v, ast.Constant) and v.value == 3.4e37:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
    return names


def _is_big(node: ast.AST, names: Set[str]) -> bool:
    return (isinstance(node, ast.Name) and node.id in names) or \
        (isinstance(node, ast.Attribute) and node.attr == "BIG")


def _clamped(mod: Module, node: ast.AST) -> bool:
    for anc in mod.ancestors(node):
        if isinstance(anc, ast.Call) and call_terminal(anc) in CLAMPS:
            return True
        if isinstance(anc, ast.stmt):
            return False
    return False


@register("sentinel")
def check(mod: Module) -> List[Finding]:
    names = _big_names(mod)
    if not names:
        return []
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.BinOp) and \
                isinstance(node.op, (ast.Add, ast.Mult)):
            if (_is_big(node.left, names) or _is_big(node.right, names)) \
                    and not _clamped(mod, node):
                out.append(Finding(
                    mod.rel, node.lineno, "sentinel-unclamped-arith",
                    "arithmetic on the BIG quasi-infinity without a "
                    "clamp: sums of sentinels overflow float32 to inf "
                    "(wrap in jnp.minimum(..., BIG))"))
        elif isinstance(node, ast.Call) and call_terminal(node) in SUMS:
            if any(_is_big(a, names) for a in node.args) and \
                    not _clamped(mod, node):
                out.append(Finding(
                    mod.rel, node.lineno, "sentinel-unclamped-arith",
                    f"'{call_terminal(node)}' over a BIG-bound operand "
                    "without a clamp: cumulative sums of the sentinel "
                    "overflow float32"))
    return out
