"""Static-analysis core: pass registry, findings, allowlist pragmas.

The substrate invariants of PRs 1-7 (one packed dispatch per merged round,
padding rows never counted, zero retraces on warm sweeps, BIG-sentinel
clamping, deprecation-shim hygiene) were enforced only at runtime — by
tier-1 tests and count-gated benchmarks.  This package makes them
*statically checkable*: each pass walks a module's AST and reports
:class:`Finding`\\ s keyed by a stable rule id, and ``tools/lint.py`` fails
CI when any survive.

Intentional exceptions are allowlisted in source with a pragma comment::

    # lint: allow[rule-id] -- why this site is exempt

placed on the flagged line or on the line directly above it.  The
justification text after ``--`` is mandatory: a pragma without one is
itself a finding (``pragma-missing-justification``), so suppressions stay
reviewable.  Several rules may share one pragma: ``allow[rule-a,rule-b]``.

Passes register themselves via :func:`register`; :func:`run` walks a tree,
parses each ``*.py`` once, runs every pass, and applies pragma
suppression.  Output shapes (human / JSON) live in :func:`render_human`
and :func:`to_json`.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence

#: ``# lint: allow[rule, rule2] -- justification``
PRAGMA_RE = re.compile(
    r"#\s*lint:\s*allow\[([A-Za-z0-9_\-, ]+)\]\s*(?:--\s*(\S.*))?")

#: rule id of the pragma-hygiene finding emitted by the runner itself
PRAGMA_RULE = "pragma-missing-justification"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""

    path: str          # path as given to run() (repo-relative in CI)
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Pragma:
    """One ``# lint: allow[...]`` comment."""

    line: int
    rules: tuple
    justification: str
    used: bool = False


@dataclasses.dataclass
class Module:
    """One parsed source file handed to every pass."""

    path: pathlib.Path
    rel: str                   # posix path relative to the lint root
    tree: ast.Module
    lines: List[str]
    pragmas: List[Pragma]
    _parents: Optional[Dict[ast.AST, ast.AST]] = None

    def parents(self) -> Dict[ast.AST, ast.AST]:
        """child node -> parent node map (built lazily, cached)."""
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        p = self.parents()
        while node in p:
            node = p[node]
            yield node


PassFn = Callable[[Module], List[Finding]]
_PASSES: Dict[str, PassFn] = {}


def register(name: str) -> Callable[[PassFn], PassFn]:
    def deco(fn: PassFn) -> PassFn:
        _PASSES[name] = fn
        return fn
    return deco


def load_default_passes() -> None:
    """Import the pass modules so their ``register`` calls run."""
    from repro.analysis import (accounting, dispatch,  # noqa: F401
                                sentinel, shims, trace)


def pass_names() -> List[str]:
    load_default_passes()
    return sorted(_PASSES)


# -- shared AST helpers -------------------------------------------------------

def call_terminal(call: ast.Call) -> Optional[str]:
    """Terminal callable name of a Call: ``a.b.c(..)`` -> ``c``."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c" when the chain is pure Name/Attribute."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_jax_jit(node: ast.AST) -> bool:
    """Whether an expression is the ``jax.jit`` callable itself."""
    return dotted(node) in ("jax.jit", "jit")


def loop_bodies(func: ast.AST) -> List[ast.AST]:
    """Every statement/expr subtree that re-executes per iteration inside
    ``func``: for/while bodies and comprehension elements (nested functions
    get their own scan, so their loops are not attributed to the parent)."""
    out: List[ast.AST] = []

    def visit(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(child, (ast.For, ast.AsyncFor, ast.While)):
                out.extend(child.body)
                out.extend(child.orelse)
            elif isinstance(child, (ast.ListComp, ast.SetComp, ast.DictComp,
                                    ast.GeneratorExp)):
                # the element/conditions run per iteration; the FIRST
                # generator's source iterable is evaluated exactly once
                if isinstance(child, ast.DictComp):
                    out.extend([child.key, child.value])
                else:
                    out.append(child.elt)
                for i, gen in enumerate(child.generators):
                    out.extend(gen.ifs)
                    if i > 0:
                        out.append(gen.iter)
            visit(child)

    visit(func)
    return out


def in_any(node: ast.AST, subtrees: Sequence[ast.AST]) -> bool:
    return any(node is t or any(node is n for n in ast.walk(t))
               for t in subtrees)


def calls_in_loops(func: ast.AST) -> List[ast.Call]:
    """All Call nodes that execute once per loop iteration in ``func``."""
    seen: List[ast.Call] = []
    for body in loop_bodies(func):
        for node in ast.walk(body):
            if isinstance(node, ast.Call) and node not in seen:
                seen.append(node)
    return seen


def module_functions(tree: ast.Module) -> List[ast.AST]:
    """Every function/method def in the module, including nested ones."""
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


# -- runner -------------------------------------------------------------------

def collect_pragmas(lines: Sequence[str]) -> List[Pragma]:
    out = []
    for i, text in enumerate(lines, start=1):
        m = PRAGMA_RE.search(text)
        if m:
            rules = tuple(r.strip() for r in m.group(1).split(",")
                          if r.strip())
            out.append(Pragma(line=i, rules=rules,
                              justification=(m.group(2) or "").strip()))
    return out


def load_module(path: pathlib.Path, root: pathlib.Path) -> Module:
    text = path.read_text()
    try:
        rel = path.relative_to(root).as_posix()
    except ValueError:
        rel = path.as_posix()
    lines = text.splitlines()
    return Module(path=path, rel=rel, tree=ast.parse(text, str(path)),
                  lines=lines, pragmas=collect_pragmas(lines))


def _suppressed(f: Finding, pragmas: List[Pragma]) -> bool:
    for p in pragmas:
        if f.rule in p.rules and f.line in (p.line, p.line + 1):
            p.used = True
            return True
    return False


def run(root: pathlib.Path, *, select: Optional[Sequence[str]] = None,
        files: Optional[Sequence[pathlib.Path]] = None):
    """Lint every ``*.py`` under ``root`` (or just ``files``).

    Returns ``(findings, stats)`` where stats counts files, pragmas in use,
    and pragmas per rule (the acceptance budget is on pragma *comments*).
    """
    load_default_passes()
    root = pathlib.Path(root)
    names = list(select) if select else sorted(_PASSES)
    unknown = [n for n in names if n not in _PASSES]
    if unknown:
        raise KeyError(f"unknown pass(es) {unknown}; have {sorted(_PASSES)}")
    paths = sorted(files) if files else sorted(root.rglob("*.py"))

    findings: List[Finding] = []
    stats = {"files": 0, "passes": names, "pragmas_used": 0,
             "pragmas": []}
    for path in paths:
        try:
            mod = load_module(path, root)
        except SyntaxError as e:
            findings.append(Finding(str(path), e.lineno or 0, "parse-error",
                                    f"cannot parse: {e.msg}"))
            continue
        stats["files"] += 1
        raw: List[Finding] = []
        for name in names:
            raw.extend(_PASSES[name](mod))
        for f in raw:
            if not _suppressed(f, mod.pragmas):
                findings.append(f)
        for p in mod.pragmas:
            if p.used and not p.justification:
                findings.append(Finding(
                    mod.rel, p.line, PRAGMA_RULE,
                    "allowlist pragma needs a justification: "
                    "# lint: allow[rule] -- <why this site is exempt>"))
            if p.used:
                stats["pragmas_used"] += 1
                stats["pragmas"].append(
                    {"path": mod.rel, "line": p.line,
                     "rules": list(p.rules),
                     "justification": p.justification})
    return sorted(findings), stats


def to_json(findings: Sequence[Finding], stats: dict) -> str:
    return json.dumps({
        "findings": [f.to_dict() for f in findings],
        "clean": not findings,
        "stats": stats,
    }, indent=2)


def render_human(findings: Sequence[Finding], stats: dict) -> str:
    lines = [f.format() for f in findings]
    lines.append(f"# {len(findings)} finding(s) across {stats['files']} "
                 f"file(s); {stats['pragmas_used']} allowlist pragma(s) "
                 "in use")
    return "\n".join(lines)
