"""shim-discipline pass — deprecation shims warn and document removal.

The v0.1 -> facade migration left four sanctioned shims
(``SubsequenceMatcher``, ``ElasticIndex``, ``EmbeddingRetriever``,
``core.distributed._batch_dist``).  The contract, enforced here: a shim
must emit its warning through the ``core/_deprecation`` plumbing
(``warn_legacy``/``warn_moved`` — these respect ``facade_construction``
suppression), and its docstring must name BOTH the replacement entry
point and the removal release (v0.2), so callers reading help() get the
migration path.

Rules
-----
``shim-missing-warn``
    A def/class whose docstring declares it deprecated but whose body
    never calls ``warn_legacy``/``warn_moved``: external callers migrate
    blind.
``shim-docstring``
    A def/class that warns (or documents deprecation) without naming the
    v0.2 removal release and a ``repro.``/facade replacement path in its
    docstring.
"""

from __future__ import annotations

import ast
import re
from typing import List

from repro.analysis.core import (Finding, Module, call_terminal,
                                 module_functions, register)

WARN_CALLS = {"warn_legacy", "warn_moved"}
DEPRECATED_RE = re.compile(r"\bdeprecat", re.IGNORECASE)
REPLACEMENT_RE = re.compile(r"repro\.|Retriever|facade")

#: the deprecation plumbing itself (its docstrings describe the mechanism)
SHIM_MACHINERY = ("core/_deprecation.py",)


def _warns(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) and call_terminal(n) in WARN_CALLS
               for n in ast.walk(node))


@register("shims")
def check(mod: Module) -> List[Finding]:
    if mod.rel.endswith(SHIM_MACHINERY):
        return []
    out: List[Finding] = []
    defs: List[ast.AST] = list(module_functions(mod.tree))
    defs += [n for n in ast.walk(mod.tree) if isinstance(n, ast.ClassDef)]
    audited_classes = set()
    for node in defs:
        if isinstance(node, ast.ClassDef):
            doc = ast.get_docstring(node) or ""
            if _warns(node) or DEPRECATED_RE.search(doc):
                audited_classes.add(node)
    for node in defs:
        doc = ast.get_docstring(node) or ""
        declared = bool(DEPRECATED_RE.search(doc))
        warns = _warns(node)
        if not declared and not warns:
            continue
        # methods of a shim class ride on the class-level docstring (the
        # class itself is audited) — don't re-audit each method that
        # carries the warn call
        if not declared and any(
                node in ast.walk(c) and node is not c
                for c in audited_classes):
            continue
        if declared and not warns:
            out.append(Finding(
                mod.rel, node.lineno, "shim-missing-warn",
                f"'{node.name}' documents itself as deprecated but never "
                "calls warn_legacy/warn_moved (core/_deprecation): "
                "external callers migrate blind"))
        if (declared or warns) and not (
                "v0.2" in doc and REPLACEMENT_RE.search(doc)):
            out.append(Finding(
                mod.rel, node.lineno, "shim-docstring",
                f"deprecation shim '{node.name}' must name the v0.2 "
                "removal release and the replacement entry point in its "
                "docstring"))
    return out
