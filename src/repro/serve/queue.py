"""Async request queue for the continuous-batching serve engine.

A :class:`Request` is one range query's full serving record: the query
window, its ε, and the lifecycle timestamps the latency accounting is
built from —

* ``t_submit``  — entered the queue (the load generator's arrival time);
* ``t_admit``   — pulled off the queue and admitted to the shared
  frontier cadence (plans primed; joins at the next round boundary);
* ``t_first_dispatch`` — first merged round that carried this request's
  rows (queue delay = ``t_first_dispatch - t_submit``);
* ``t_complete`` — all shard-local plans exhausted, hits finalized.

The :class:`RequestQueue` itself is a small thread-safe FIFO: producers
(:class:`~repro.serve.loadgen.OpenLoopLoadGen`, CLI threads, tests) call
:meth:`~RequestQueue.submit`; the engine's tick drains it with
:meth:`~RequestQueue.take` up to the admission budget.  Timestamps are
caller-supplied so the same machinery serves both wall-clock serving and
the deterministic virtual-clock benchmarks.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import threading
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One in-flight range query and its latency accounting row."""
    rid: int
    query: np.ndarray
    eps: float
    tag: Optional[object] = None
    t_submit: float = 0.0
    t_admit: float = math.nan
    t_first_dispatch: float = math.nan
    t_complete: float = math.nan
    rounds: int = 0                       # merged rounds this request rode in
    hits: Optional[List[int]] = None      # sorted global window ids
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def finish(self, hits: List[int], now: float) -> None:
        self.hits = hits
        self.t_complete = now
        self._done.set()

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until served; returns the sorted global hit ids."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served in time")
        assert self.hits is not None
        return self.hits

    @property
    def latency(self) -> float:
        """End-to-end: submit -> complete."""
        return self.t_complete - self.t_submit

    @property
    def queue_delay(self) -> float:
        """Submit -> first merged round carrying this request's rows."""
        return self.t_first_dispatch - self.t_submit


class RequestQueue:
    """Thread-safe FIFO between producers and the engine tick."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: collections.deque = collections.deque()
        self._next_rid = 0
        self.submitted = 0

    def submit(self, query: np.ndarray, eps: float, *,
               tag: Optional[object] = None, now: float = 0.0) -> Request:
        req = Request(rid=-1, query=np.asarray(query), eps=float(eps),
                      tag=tag, t_submit=float(now))
        with self._lock:
            req.rid = self._next_rid
            self._next_rid += 1
            self._items.append(req)
            self.submitted += 1
        return req

    def take(self, limit: int) -> List[Request]:
        """Pop up to ``limit`` requests in arrival order."""
        out: List[Request] = []
        with self._lock:
            while self._items and len(out) < limit:
                out.append(self._items.popleft())
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)
