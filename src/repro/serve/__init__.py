"""Continuous-batching retrieval serving (PR 9).

The serve layer turns the round-based fleet substrate into a front end
for asynchronous traffic: requests admitted mid-flight join the shared
frontier cadence at the next round boundary, every in-flight request's
next round merges into ONE packed device dispatch per tick, and a live
fleet snapshots/restores so ``resize()`` swaps reshards in with zero
downtime.  See ``docs/architecture.md`` ("Serving layer").
"""

from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.loadgen import OpenLoopLoadGen, poisson_schedule
from repro.serve.queue import Request, RequestQueue
from repro.serve.snapshot import FleetSnapshotManager

__all__ = [
    "FleetSnapshotManager",
    "OpenLoopLoadGen",
    "Request",
    "RequestQueue",
    "ServeConfig",
    "ServeEngine",
    "poisson_schedule",
]
