"""Batched serving engine: prefill + decode with a persistent KV cache.

A single-host stand-in for the multi-pod serving fleet the dry-run lowers:
requests are batched, prefilled once, then decoded step-by-step; slots
free as sequences finish (continuous batching light).  The same step
functions are what the decode_* dry-run cells lower at production shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import Ctx, NOCTX


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 256
    temperature: float = 0.0  # 0 = greedy
    eos_token: Optional[int] = None


class Engine:
    def __init__(self, model, cfg, params, scfg: ServeConfig,
                 ctx: Ctx = NOCTX, seed: int = 0):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.ctx = ctx
        self.rng = np.random.default_rng(seed)
        self._decode = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, cfg, ctx))
        self._prefill = jax.jit(
            lambda p, b: model.forward(p, b, cfg, ctx, return_cache=True))

    def _pad_cache(self, cache):
        """Grow cache length axes to max_seq (prefill built them at S0)."""
        def grow(path_key, x):
            if not isinstance(x, jnp.ndarray) or x.ndim < 3:
                return x
            if path_key in ("k", "v") or path_key.endswith("ckv") \
                    or path_key.endswith("kr"):
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, self.scfg.max_seq - x.shape[2])
                return jnp.pad(x, pad)
            return x
        return {k: grow(k, v) for k, v in cache.items()}

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        logits = logits[:, -1, :self.cfg.vocab]
        if self.scfg.temperature <= 0:
            return logits.argmax(-1)
        z = logits / self.scfg.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self.rng.choice(len(row), p=row) for row in p])

    def generate(self, prompts: List[np.ndarray], max_new: int = 32
                 ) -> List[np.ndarray]:
        """Greedy/temperature decode for a batch of token prompts."""
        assert len(prompts) <= self.scfg.max_batch
        B = len(prompts)
        S0 = max(len(p) for p in prompts)
        toks = np.zeros((B, S0), np.int32)
        for i, p in enumerate(prompts):
            toks[i, S0 - len(p):] = p  # left-pad (simplest alignment)
        out = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        if len(out) == 3:
            logits, _, cache = out
        else:
            logits, cache = out
        cache = self._pad_cache(cache)
        done = np.zeros((B,), bool)
        new_tokens: List[List[int]] = [[] for _ in range(B)]
        cur = self._sample(np.asarray(logits, np.float32))
        for i in range(B):
            new_tokens[i].append(int(cur[i]))
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(cur[:, None], jnp.int32))
            cur = self._sample(np.asarray(logits, np.float32))
            for i in range(B):
                if not done[i]:
                    tok = int(cur[i])
                    new_tokens[i].append(tok)
                    if self.scfg.eos_token is not None \
                            and tok == self.scfg.eos_token:
                        done[i] = True
            if done.all():
                break
        return [np.array(t, np.int32) for t in new_tokens]

    def hidden_states(self, tokens: np.ndarray) -> np.ndarray:
        """Final-layer hidden states for embedding-space retrieval."""
        # run forward and grab pre-unembed activations by re-running the
        # model body; simplest correct route: logits @ pseudo-inverse is
        # wrong, so models expose forward with return_cache for caches only;
        # instead we recompute embeddings from logits' pre-projection via a
        # dedicated capture in the model would complicate the API — the
        # retrieval layer uses unembedded logits-space windows instead.
        raise NotImplementedError(
            "use repro.core.embedding_retrieval.embed_windows")
