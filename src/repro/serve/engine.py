"""Continuous-batching retrieval serve engine.

(Until PR 9 this module held a dormant LLM prefill/decode engine — an
artifact of the training stack with no retrieval surface, including the
never-implemented ``Engine.hidden_states`` stub.  It is replaced
wholesale: serving *retrieval* is the subsystem the substrate was built
for.)

The engine turns the round-based fleet substrate into a front end for
asynchronous traffic.  Requests submitted at any time land in a
:class:`~repro.serve.queue.RequestQueue`; each :meth:`ServeEngine.tick`

1. applies a pending fleet swap (zero-downtime resize) at the round
   boundary,
2. admits queued requests up to ``max_inflight`` — each request becomes
   one :class:`~repro.core.batch_engine.ShardPlans` group per alive
   shard, joined to the shared cadence via
   :meth:`FleetBatchEngine.admit`, and
3. advances EVERY in-flight request's frontier by ONE merged round —
   one packed ``kernels/dispatch.packed_batch`` call across all
   requests, shards, and length buckets — retiring finished requests'
   rows immediately.

Admission policy: ``"tick"`` (default) merges newcomers straight into
the next shared round — strictly fewest dispatches; ``"greedy"`` gives
newcomers one dedicated round first when older requests are already
mid-flight, trading an extra dispatch for not making deep-frontier
stragglers gate a newcomer's first rows.

Zero-downtime resize: :meth:`ServeEngine.resize` snapshots the live
fleet (:class:`~repro.serve.snapshot.FleetSnapshotManager` — atomic
write, latest pointer), restores a clone, reshards the CLONE while the
original keeps serving, then swaps at the next round boundary.
In-flight requests captured their shard groups (plans + gids) at admit
time and finish against the old arrays — hit sets are
shard-layout-invariant over the same windows, so exactness holds across
the swap; new admissions serve from the resharded fleet.

Latency accounting rides the request records themselves
(submit/admit/first-dispatch/complete timestamps, rounds carried);
:meth:`ServeEngine.latency_stats` reduces them to p50/p95/p99.  Two
clocks drive the same machinery: :meth:`start`/:meth:`submit` serve
wall-clock traffic on a background thread, :meth:`run_schedule` replays
a deterministic arrival schedule on a virtual clock — the count-strict
benchmark gate (``benchmarks/bench_serve.py``) uses the latter.
"""

from __future__ import annotations

import dataclasses
import math
import tempfile
import threading
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.batch_engine import FleetBatchEngine, ShardPlans
from repro.serve.queue import Request, RequestQueue
from repro.serve.snapshot import FleetSnapshotManager

#: admission policies (see the module docstring)
ADMISSION_POLICIES = ("tick", "greedy")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serve-engine knobs (mirrored by ``RetrievalConfig.serve_*``)."""
    eps: float = 1.0                    # default query radius
    max_inflight: int = 32              # in-flight request cap
    admission: str = "tick"             # "tick" | "greedy"
    snapshot_dir: Optional[str] = None  # default: a fresh temp dir
    snapshot_keep: int = 3

    def __post_init__(self):
        if self.max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1; got {self.max_inflight}")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"admission must be one of {ADMISSION_POLICIES}; "
                f"got {self.admission!r}")


class ServeEngine:
    """Continuous-batching front end over an ElasticIndex fleet."""

    def __init__(self, fleet, config: Optional[ServeConfig] = None, *,
                 clock=time.monotonic):
        self.fleet = fleet
        self.config = config or ServeConfig()
        self.clock = clock
        self.queue = RequestQueue()
        evaluate, fused = fleet._round_evaluator()
        # ONE long-lived engine: the evaluator closes over the distance
        # name + interpret flag only (shape-generic), so it keeps serving
        # across fleet swaps
        self._engine = FleetBatchEngine(evaluate, fused=fused)
        #: bid -> (request, per-group gids captured at admit time)
        self._inflight: Dict[int, Tuple[Request, List[np.ndarray]]] = {}
        self.completed: List[Request] = []
        self.swaps = 0
        self._pending_swap = None
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._snap: Optional[FleetSnapshotManager] = None

    # -- submission ---------------------------------------------------------

    def submit(self, query: np.ndarray, eps: Optional[float] = None, *,
               tag: Optional[object] = None,
               now: Optional[float] = None) -> Request:
        """Enqueue a range query; returns its handle (``req.result()``
        blocks until served when the engine runs on a thread)."""
        return self.queue.submit(
            query, self.config.eps if eps is None else eps, tag=tag,
            now=self.clock() if now is None else now)

    # -- admission + rounds -------------------------------------------------

    def _lb_hook(self, fleet):
        """Envelope-cascade hook over THIS fleet's precomputed per-window
        envelopes (same tier as ``ElasticIndex._round_query``); bound to
        each admitted group so pre- and post-swap requests screen against
        the fleet that admitted them."""
        if fleet.lb_cascade != "envelope":
            return None
        from repro.distances import bounds as dist_bounds
        envs = {}
        for si, w in enumerate(fleet.workers):
            s = fleet.shards.get(w)
            if s is not None and s.flat.envelopes is not None:
                envs[si] = s.flat.envelopes
        if not envs:
            return None
        name = fleet.dist.name

        def hook(shard, idxs, q, q_len):
            e = envs[shard].take(idxs)
            xs = np.repeat(q[None], len(idxs), 0)
            return dist_bounds.lb_envelope_rows(
                name, xs, np.full(len(idxs), q_len, np.int64),
                e.lo, e.hi, e.mass)

        return hook

    def _admit_one(self, req: Request, now: float) -> Optional[int]:
        fleet = self.fleet
        q = np.asarray(req.query)
        qpad, q_lens = q[None], np.asarray([len(q)], np.int64)
        hook = self._lb_hook(fleet)
        groups: List[ShardPlans] = []
        gids: List[np.ndarray] = []
        for si, w in enumerate(fleet.workers):
            s = fleet.shards.get(w)
            if s is None:
                continue
            groups.append(ShardPlans(
                shard=si, data=s.net.data,
                plans=[s.net.range_query_plan(req.eps)],
                queries=qpad, q_lens=q_lens, lb=hook))
            gids.append(s.gids)
        req.t_admit = now
        bid = self._engine.admit(groups, req.eps)
        self._inflight[bid] = (req, gids)
        if self._engine.is_finished(bid):  # e.g. an empty fleet
            self._finalize(bid, now)
            return None
        return bid

    def _finalize(self, bid: int, now: float) -> Request:
        req, gids = self._inflight.pop(bid)
        per_group = self._engine.results(bid)
        hits = set()
        for g, res in zip(gids, per_group):
            hits.update(int(g[x]) for x in res[0])
        req.finish(sorted(hits), now)
        self.completed.append(req)
        return req

    def _round(self, now: float,
               only: Optional[Set[int]] = None) -> List[Request]:
        """One merged round over the in-flight set (or the ``only``
        subset); stamps first-dispatch times, retires finished rows."""
        parts = self._engine.batches_in_flight()
        if only is not None:
            parts &= only
        for bid in parts:
            req = self._inflight[bid][0]
            req.rounds += 1
            if math.isnan(req.t_first_dispatch):
                req.t_first_dispatch = now
        return [self._finalize(bid, now)
                for bid in self._engine.step(only=only)]

    def tick(self, now: Optional[float] = None) -> List[Request]:
        """One scheduler beat: swap -> admit -> (greedy round) -> shared
        round.  Returns the requests completed this tick."""
        with self._lock:
            now = self.clock() if now is None else now
            if self._pending_swap is not None:  # round boundary: safe swap
                self.fleet = self._pending_swap
                self._pending_swap = None
                self.swaps += 1
            had_inflight = bool(self._inflight)
            budget = self.config.max_inflight - len(self._inflight)
            newly: Set[int] = set()
            for req in self.queue.take(max(budget, 0)):
                bid = self._admit_one(req, now)
                if bid is not None:
                    newly.add(bid)
            done: List[Request] = []
            if self.config.admission == "greedy" and had_inflight and newly:
                # dedicated first round: newcomers dispatch immediately
                # instead of waiting on the shared cadence
                done.extend(self._round(now, only=newly))
            if self._engine.active:
                done.extend(self._round(now))
            return done

    # -- zero-downtime resize ----------------------------------------------

    def _snapshot_manager(self) -> FleetSnapshotManager:
        if self._snap is None:
            d = self.config.snapshot_dir or tempfile.mkdtemp(
                prefix="repro-serve-snap-")
            self._snap = FleetSnapshotManager(
                d, keep=self.config.snapshot_keep)
        return self._snap

    def snapshot(self, block: bool = True) -> int:
        """Snapshot the live fleet; returns the snapshot step."""
        with self._lock:
            return self._snapshot_manager().save(self.fleet, block=block)

    def resize(self, workers: Sequence[str], *, block: bool = True) -> None:
        """Reshard with zero downtime: snapshot -> restore a clone ->
        resize the CLONE (the live fleet keeps serving) -> stage the swap
        for the next round boundary.  ``block=False`` runs the rebuild on
        a background thread (the wall-clock serving mode)."""
        workers = list(workers)

        def work():
            snap = self._snapshot_manager()
            with self._lock:
                step = snap.save(self.fleet, block=True)
            clone = snap.restore(step)
            clone.resize(workers)           # off the serving path
            with self._lock:
                self._pending_swap = clone

        if block:
            work()
        else:
            threading.Thread(target=work, daemon=True).start()

    # -- deterministic virtual-clock serving --------------------------------

    def run_schedule(self, queries: Sequence[np.ndarray],
                     arrivals: Sequence[float], *,
                     eps: Optional[float] = None, round_cost: float = 1.0,
                     resize_at: Optional[float] = None,
                     resize_to: Optional[Sequence[str]] = None
                     ) -> List[Request]:
        """Replay an arrival schedule on a virtual clock (deterministic:
        fixed arrivals + fixed ``round_cost`` per merged round -> identical
        admission pattern, dispatch counts, and latency numbers every run).
        Optionally triggers a zero-downtime ``resize(resize_to)`` at
        virtual time ``resize_at``.  Returns requests in submit order."""
        arrivals = np.asarray(arrivals, np.float64)
        assert len(queries) == len(arrivals)
        reqs: List[Request] = []
        i, n = 0, len(queries)
        t = 0.0
        resized = resize_at is None
        while True:
            if not resized and t >= resize_at:
                self.resize(resize_to)
                resized = True
            while i < n and arrivals[i] <= t:
                reqs.append(self.submit(queries[i], eps=eps,
                                        now=float(arrivals[i])))
                i += 1
            before = self._engine.rounds
            self.tick(now=t)
            t += round_cost * max(1, self._engine.rounds - before)
            if self._engine.active or len(self.queue):
                continue
            if i >= n and resized:
                break
            # idle: jump the clock to the next event (arrival or resize)
            pending = [float(arrivals[i])] if i < n else []
            if not resized:
                pending.append(float(resize_at))
            t = max(t, min(pending))
        return reqs

    # -- wall-clock serving -------------------------------------------------

    def start(self) -> "ServeEngine":
        """Serve on a background thread until :meth:`close`."""
        assert self._thread is None, "already started"
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                with self._lock:
                    idle = not (self._engine.active or len(self.queue)
                                or self._pending_swap is not None)
                if idle:
                    time.sleep(1e-3)
                else:
                    self.tick()

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()
        return self

    def close(self, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the serving thread; ``drain`` serves everything queued or
        in flight first."""
        if self._thread is None:
            return
        if drain:
            deadline = time.monotonic() + timeout
            while time.monotonic() < deadline:
                with self._lock:
                    busy = (self._engine.active or len(self.queue)
                            or self._pending_swap is not None)
                if not busy:
                    break
                time.sleep(1e-3)
        self._stop.set()
        self._thread.join(timeout)
        self._thread = None

    # -- accounting ---------------------------------------------------------

    def engine_stats(self) -> Dict[str, int]:
        """Shared-cadence totals (merged rounds, eval split, swaps)."""
        e = self._engine
        return {"rounds": e.rounds, "exact_evals": e.exact_evals,
                "verdict_evals": e.verdict_evals,
                "fused_pruned": e.fused_pruned,
                "lb_rows": e.lb_rows, "lb_pruned": e.lb_pruned,
                "submitted": self.queue.submitted,
                "completed": len(self.completed), "swaps": self.swaps}

    def latency_stats(self) -> Dict[str, float]:
        """Per-request latency percentiles over the completed set (clock
        units: seconds in wall-clock mode, virtual time under
        :meth:`run_schedule`)."""
        done = [r for r in self.completed if r.done]
        if not done:
            return {"n": 0}
        lat = np.array([r.latency for r in done], np.float64)
        out = {"n": len(done),
               "p50": float(np.percentile(lat, 50)),
               "p95": float(np.percentile(lat, 95)),
               "p99": float(np.percentile(lat, 99)),
               "mean": float(lat.mean()),
               "mean_rounds": float(np.mean([r.rounds for r in done]))}
        qd = np.array([r.queue_delay for r in done
                       if not math.isnan(r.t_first_dispatch)], np.float64)
        if len(qd):
            out["queue_p50"] = float(np.percentile(qd, 50))
            out["queue_p99"] = float(np.percentile(qd, 99))
        return out
