"""Zero-downtime fleet snapshot/restore for the serve layer.

A live :class:`~repro.launch.elastic.ElasticIndex` is, per shard, three
structures the reference net's O(n) layout keeps cheap to dump: the host
node graph (``ReferenceNet.nodes`` — id/level/radius scalars plus ragged
child/parent adjacency), the device :class:`~repro.core.distributed.FlatNet`
(dense pivot/member arrays + precomputed envelopes), and the ``gids`` map
from local rows to global window ids.  This module serializes all of it to
ONE ``.npz`` + ``meta.json`` per snapshot through the training stack's
:class:`~repro.train.checkpoint.CheckpointManager` — inheriting its atomic
tmp-dir + fsync + rename write, ``latest`` pointer, background-thread
async save, and retention — and restores a fully-serving clone **without
spending a single distance evaluation**: nodes, flats, and envelopes are
rebuilt from arrays, never recomputed, and the per-shard counter buckets
are restored verbatim so ``eval_count()`` parity holds across a
round-trip.

The serve engine's zero-downtime ``resize()`` is built on this: snapshot
the live fleet (blocking — the arrays are copied out under the caller's
control), restore a clone, reshard the *clone* while the original keeps
serving in-flight traffic, then swap atomically at a round boundary.
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional

import numpy as np

from repro.train.checkpoint import CheckpointManager


def _shard_arrays(wi: int, shard) -> Dict[str, np.ndarray]:
    """Dump one shard's net + flat + gids as flat npz-ready arrays."""
    net, flat = shard.net, shard.flat
    node_ids = sorted(net.nodes)
    child_cnt, parent_cnt = [], []
    child_ids, child_dist, child_level, parent_ids = [], [], [], []
    for x in node_ids:
        n = net.nodes[x]
        child_cnt.append(len(n.children))
        parent_cnt.append(len(n.parents))
        child_ids.extend(n.children)
        child_dist.extend(n.child_dist)
        child_level.extend(n.child_level)
        parent_ids.extend(n.parents)
    p = f"s{wi}/"
    out = {
        p + "gids": np.array(shard.gids, np.int64),
        p + "sdata": np.array(net.counter.data),
        p + "node_ids": np.array(node_ids, np.int64),
        p + "node_level": np.array([net.nodes[x].level for x in node_ids],
                                   np.int64),
        p + "node_subr": np.array([net.nodes[x].sub_radius
                                   for x in node_ids], np.float64),
        p + "child_cnt": np.array(child_cnt, np.int64),
        p + "child_ids": np.array(child_ids, np.int64),
        p + "child_dist": np.array(child_dist, np.float64),
        p + "child_level": np.array(child_level, np.int64),
        p + "parent_cnt": np.array(parent_cnt, np.int64),
        p + "parent_ids": np.array(parent_ids, np.int64),
        p + "pivots": np.array(flat.pivots),
        p + "pivot_radius": np.array(flat.pivot_radius),
        p + "members": np.array(flat.members),
        p + "member_dist": np.array(flat.member_dist),
        p + "pivot_ids": np.array(flat.pivot_ids, np.int64),
    }
    if flat.envelopes is not None:
        e = flat.envelopes
        out.update({p + "env_lo": np.array(e.lo), p + "env_hi": np.array(e.hi),
                    p + "env_mass": np.array(e.mass),
                    p + "env_cum": np.array(e.cum),
                    p + "env_lens": np.array(e.lens)})
    return out


def _shard_meta(shard) -> dict:
    net = shard.net
    c = net.counter
    return {"root": int(net.root), "top_level": int(net.top_level),
            "n_pivots": int(shard.flat.n_pivots),
            "has_env": shard.flat.envelopes is not None,
            "count": c.count, "dispatches": c.dispatches,
            "lb_count": c.lb_count, "build_count": c.build_count,
            "build_dispatches": c.build_dispatches,
            "lb_tier_rows": c.lb_tier_rows,
            "lb_tier_pruned": c.lb_tier_pruned}


class FleetSnapshotManager:
    """Snapshot/restore a live fleet; atomic writes via CheckpointManager."""

    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self._ckpt = CheckpointManager(directory, keep=keep,
                                       async_save=async_save)

    # -- save ---------------------------------------------------------------

    def save(self, fleet, step: Optional[int] = None,
             block: bool = False) -> int:
        """Snapshot ``fleet`` (an ElasticIndex).  Arrays are copied out
        synchronously — the fleet may keep mutating (resize, append) the
        moment this returns — and the disk write runs on the checkpoint
        manager's background thread unless ``block``."""
        if step is None:
            last = self._ckpt.latest_step()
            step = 0 if last is None else last + 1
        arrays: Dict[str, np.ndarray] = {"fleet/data": np.array(fleet.data)}
        shard_meta: Dict[str, dict] = {}
        for wi, w in enumerate(fleet.workers):
            s = fleet.shards.get(w)
            if s is None:
                continue
            arrays.update(_shard_arrays(wi, s))
            shard_meta[str(wi)] = _shard_meta(s)
        meta = {"kind": "fleet_snapshot",
                "dist": fleet.dist.name,
                "workers": list(fleet.workers),
                "eps_prime": fleet.eps_prime, "tight": fleet.tight,
                "backend": fleet.backend, "max_cohort": fleet.max_cohort,
                "interpret": fleet.interpret, "fleet_mode": fleet.fleet_mode,
                "lb_cascade": fleet.lb_cascade,
                "kernel_exec": fleet.kernel_exec,
                "kernel_tile": fleet.kernel_tile,
                "retired": dict(fleet._retired),
                "device_stats": dict(fleet.device_stats),
                "shards": shard_meta}
        self._ckpt.save(step, arrays, extra=meta, block=block)
        return step

    def wait(self) -> None:
        self._ckpt.wait()

    def latest_step(self) -> Optional[int]:
        return self._ckpt.latest_step()

    # -- restore ------------------------------------------------------------

    def restore(self, step: Optional[int] = None):
        """Rebuild a fully-serving ElasticIndex clone from a snapshot.

        Zero distance evaluations: the node graph, flat arrays, envelopes,
        and counter buckets are restored verbatim, so hit sets AND
        ``{query, build}`` counts match the never-snapshotted fleet."""
        from repro.core.counter import CountedDistance
        from repro.core.distributed import FlatNet
        from repro.core.refnet import Node, ReferenceNet
        from repro.distances import base as dist_base
        from repro.distances.bounds import EnvelopeSet
        from repro.launch import elastic

        if step is None:
            step = self._ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(f"no fleet snapshot in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        with np.load(path / "state.npz") as z:
            arrays = {k: z[k] for k in z.files}
        meta = json.loads((path / "meta.json").read_text())
        if meta.get("kind") != "fleet_snapshot":
            raise ValueError(f"{path} is not a fleet snapshot")

        dist = dist_base.require_metric(meta["dist"])
        fleet = elastic.ElasticIndex.__new__(elastic.ElasticIndex)
        fleet.dist = dist
        fleet.data = arrays["fleet/data"]
        fleet.eps_prime = meta["eps_prime"]
        fleet.tight = meta["tight"]
        fleet.backend = meta["backend"]
        fleet.max_cohort = meta["max_cohort"]
        fleet.interpret = meta["interpret"]
        fleet.fleet_mode = meta["fleet_mode"]
        fleet.lb_cascade = meta["lb_cascade"]
        # absent in pre-PR-10 snapshots: fall back to the registry policy
        fleet.kernel_exec = meta.get("kernel_exec")
        fleet.kernel_tile = meta.get("kernel_tile")
        fleet.workers = list(meta["workers"])
        # rendezvous assignment is a pure function of (n windows, workers)
        fleet.assignment = elastic.assign(range(len(fleet.data)),
                                          fleet.workers)
        fleet._retired = {k: int(v) for k, v in meta["retired"].items()}
        fleet._merged = None
        fleet._round_eval = None
        fleet.device_stats = {k: int(v)
                              for k, v in meta["device_stats"].items()}
        fleet.shards = {}
        for wi, w in enumerate(fleet.workers):
            sm = meta["shards"].get(str(wi))
            if sm is None:
                fleet.shards[w] = None
                continue
            p = f"s{wi}/"
            sdata = arrays[p + "sdata"]
            counter = CountedDistance(dist, sdata, backend=fleet.backend)
            counter.count = int(sm["count"])
            counter.dispatches = int(sm["dispatches"])
            counter.lb_count = int(sm["lb_count"])
            counter.build_count = int(sm["build_count"])
            counter.build_dispatches = int(sm["build_dispatches"])
            counter.lb_tier_rows = dict(sm["lb_tier_rows"])
            counter.lb_tier_pruned = dict(sm["lb_tier_pruned"])
            net = ReferenceNet(dist, counter.data,
                               eps_prime=fleet.eps_prime,
                               tight_bounds=fleet.tight, counter=counter)
            net.root = sm["root"]
            net.top_level = sm["top_level"]
            node_ids = arrays[p + "node_ids"]
            levels = arrays[p + "node_level"]
            subrs = arrays[p + "node_subr"]
            ccnt, pcnt = arrays[p + "child_cnt"], arrays[p + "parent_cnt"]
            coff = np.concatenate([[0], np.cumsum(ccnt)])
            poff = np.concatenate([[0], np.cumsum(pcnt)])
            cids = arrays[p + "child_ids"]
            cdist = arrays[p + "child_dist"]
            clevel = arrays[p + "child_level"]
            pids = arrays[p + "parent_ids"]
            for k, x in enumerate(node_ids):
                a, b = int(coff[k]), int(coff[k + 1])
                pa, pb = int(poff[k]), int(poff[k + 1])
                net.nodes[int(x)] = Node(
                    idx=int(x), level=int(levels[k]),
                    children=[int(c) for c in cids[a:b]],
                    child_dist=[float(d) for d in cdist[a:b]],
                    child_level=[int(c) for c in clevel[a:b]],
                    parents=[int(c) for c in pids[pa:pb]],
                    sub_radius=float(subrs[k]))
            envs = None
            if sm["has_env"]:
                envs = EnvelopeSet(arrays[p + "env_lo"],
                                   arrays[p + "env_hi"],
                                   arrays[p + "env_mass"],
                                   arrays[p + "env_cum"],
                                   arrays[p + "env_lens"])
            flat = FlatNet(pivots=arrays[p + "pivots"],
                           pivot_radius=arrays[p + "pivot_radius"],
                           members=arrays[p + "members"],
                           member_dist=arrays[p + "member_dist"],
                           data=counter.data,
                           n_pivots=int(sm["n_pivots"]),
                           dist_name=dist.name,
                           pivot_ids=arrays[p + "pivot_ids"],
                           envelopes=envs)
            fleet.shards[w] = elastic._Shard(net=net, flat=flat,
                                             gids=arrays[p + "gids"])
        return fleet
