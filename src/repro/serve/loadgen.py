"""Open-loop Poisson load generation for the serve engine.

Open-loop means arrivals are scheduled ahead of time from the target rate
and submitted on schedule *regardless of completions* — the generator
never waits for the engine, so queueing delay under overload is measured
honestly instead of being hidden by closed-loop back-pressure.

Two consumption modes share one schedule:

* :func:`poisson_schedule` — deterministic, seeded arrival times; the
  virtual-clock benchmark (``benchmarks/bench_serve.py``) feeds these
  straight into :meth:`~repro.serve.engine.ServeEngine.run_schedule`, so
  the count-strict gate sees identical arrivals every run.
* :class:`OpenLoopLoadGen` — a wall-clock thread that submits the same
  schedule against a running engine for real latency percentiles.
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

import numpy as np


def poisson_schedule(qps: float, duration: float, *,
                     seed: int = 0) -> np.ndarray:
    """Arrival times of a Poisson process at rate ``qps`` over
    ``[0, duration)`` — i.i.d. exponential gaps, deterministic per seed."""
    if qps <= 0:
        raise ValueError(f"qps must be positive; got {qps}")
    rng = np.random.default_rng(seed)
    # over-draw, then trim: P(fewer than 4x expected) is astronomically high
    n = max(16, int(4 * qps * duration))
    gaps = rng.exponential(1.0 / qps, size=n)
    t = np.cumsum(gaps)
    out = t[t < duration]
    while len(t) and t[-1] < duration:  # pathological seed: extend
        t = np.concatenate([t, t[-1] + np.cumsum(
            rng.exponential(1.0 / qps, size=n))])
        out = t[t < duration]
    return np.asarray(out, np.float64)


class OpenLoopLoadGen:
    """Submit a fixed query list on a wall-clock Poisson schedule.

    ``start()`` launches the submission thread; ``join()`` waits for the
    schedule to drain and returns the submitted
    :class:`~repro.serve.queue.Request` handles (completion is the
    engine's business — call ``req.result()`` / ``engine.close(drain=True)``
    to wait for answers)."""

    def __init__(self, engine, queries: Sequence[np.ndarray], qps: float,
                 *, eps: Optional[float] = None, seed: int = 0):
        self.engine = engine
        self.queries = [np.asarray(q) for q in queries]
        self.eps = eps
        # exactly ONE arrival per query (i.i.d. exponential gaps at rate
        # qps) — a duration-trimmed draw could come up short and silently
        # drop submissions from the tail of the list
        rng = np.random.default_rng(seed)
        self.schedule = np.cumsum(
            rng.exponential(1.0 / qps, size=len(self.queries)))
        self.requests: List[object] = []
        self._thread: Optional[threading.Thread] = None

    def _run(self) -> None:
        t0 = time.monotonic()
        for q, at in zip(self.queries, self.schedule):
            delay = t0 + float(at) - time.monotonic()
            if delay > 0:
                time.sleep(delay)
            self.requests.append(self.engine.submit(q, eps=self.eps))

    def start(self) -> "OpenLoopLoadGen":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def join(self, timeout: Optional[float] = None) -> List[object]:
        assert self._thread is not None, "start() first"
        self._thread.join(timeout)
        return self.requests
