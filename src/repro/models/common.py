"""Shared model plumbing: embeddings, scan-over-layers, head padding.

All models expose the same functional interface:

* ``param_defs(cfg, tp)``  -> ParamDef pytree (tp = model-axis size, used to
  pad attention heads to a shardable multiple; extra heads are masked out in
  the forward pass so the architecture's function is unchanged);
* ``forward(params, batch, cfg, ctx, return_cache=False)`` -> logits
  (and caches when prefilling);
* ``decode_step(params, cache, tokens, pos, cfg, ctx)`` -> (logits, cache);
* ``cache_defs(cfg, B, S, tp)`` -> ParamDef pytree for the decode cache.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Ctx, NOCTX
from repro.models.params import ParamDef


def embed_defs(cfg):
    V = cfg.vocab_padded()
    return {
        "tok": ParamDef((V, cfg.d_model), ("tensor", "embed")),
        "out": ParamDef((cfg.d_model, V), ("embed", "tensor")),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="ones"),
    }


def embed_tokens(params, tokens, cfg, ctx: Ctx):
    h = jnp.take(params["tok"], tokens, axis=0)
    return ctx.constrain(h, "batch", "seq", None)


def maybe_prepend_embeds(h, batch, ctx: Ctx):
    """Modality frontend stub: precomputed frame/patch embeddings are
    prepended to (or replace) the token embeddings."""
    embeds = batch.get("embeds")
    if embeds is None:
        return h
    if h is None:
        return embeds
    return jnp.concatenate([embeds.astype(h.dtype), h], axis=1)


def unembed(params, h, cfg, ctx: Ctx):
    from repro.models.layers import rms_norm
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["out"])
    return ctx.constrain(logits, "batch", "seq", "tensor")


def head_mask(cfg, tp: int, dtype=jnp.bfloat16):
    """1 for real heads, 0 for TP-padding heads (None if no padding)."""
    He = cfg.heads_padded(tp)
    if He == cfg.n_heads:
        return None
    m = (jnp.arange(He) < cfg.n_heads).astype(dtype)
    return m


def scan_blocks(block_fn, h, xs_trees: tuple, *, remat=False,
                carry_extra=None):
    """lax.scan over layer-stacked params.

    ``xs_trees`` is a tuple of layer-stacked pytrees; the block unpacks the
    per-layer slice tuple:  block_fn((h, extra), (p, ...)) -> ((h, extra), ys)
    """
    fn = block_fn
    if remat:
        fn = jax.checkpoint(block_fn,
                            policy=jax.checkpoint_policies.nothing_saveable)
    init = (h, carry_extra)
    (h, carry_extra), ys = jax.lax.scan(fn, init, xs_trees)
    return h, carry_extra, ys


def stack_layer_defs(defs, n_layers: int):
    """Prepend a 'layers' axis to every ParamDef in a block's def tree."""
    return jax.tree.map(
        lambda d: ParamDef((n_layers,) + d.shape, ("layers",) + d.axes,
                           init=d.init, fan_in=d.fan_in),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
