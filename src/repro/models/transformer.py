"""Dense decoder-only transformer (llama/qwen family).

Covers: qwen3-4b (qk_norm), qwen2-72b / qwen2.5-32b (QKV bias),
smollm-360m, musicgen-large (audio-token backbone), internvl2-76b
(VLM backbone with patch-embedding prefix stub).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.layers import (Ctx, NOCTX, apply_rope, attn_chunked,
                                 attn_decode, attn_full, gated_mlp, rms_norm,
                                 rope_tables, update_cache)
from repro.models.params import ParamDef


def _kv_axis(cfg, tp: int):
    return "tensor" if (tp > 1 and cfg.n_kv_heads % tp == 0) else None


def block_defs(cfg, tp: int = 1):
    d, hd = cfg.d_model, cfg.head_dim
    He = cfg.heads_padded(tp)
    Hkv = cfg.n_kv_heads
    kv_ax = _kv_axis(cfg, tp)
    defs = {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "ln2": ParamDef((d,), (None,), init="ones"),
        "wq": ParamDef((d, He, hd), ("embed", "tensor", None), fan_in=d),
        "wk": ParamDef((d, Hkv, hd), ("embed", kv_ax, None), fan_in=d),
        "wv": ParamDef((d, Hkv, hd), ("embed", kv_ax, None), fan_in=d),
        "wo": ParamDef((He, hd, d), ("tensor", None, "embed"), fan_in=He * hd),
        "wg": ParamDef((d, cfg.d_ff), ("embed", "tensor"), fan_in=d),
        "wu": ParamDef((d, cfg.d_ff), ("embed", "tensor"), fan_in=d),
        "wd": ParamDef((cfg.d_ff, d), ("tensor", "embed"), fan_in=cfg.d_ff),
    }
    if cfg.qkv_bias:
        defs.update({
            "bq": ParamDef((He, hd), ("tensor", None), init="zeros"),
            "bk": ParamDef((Hkv, hd), (kv_ax, None), init="zeros"),
            "bv": ParamDef((Hkv, hd), (kv_ax, None), init="zeros"),
        })
    if cfg.qk_norm:
        defs.update({
            "qnorm": ParamDef((hd,), (None,), init="ones"),
            "knorm": ParamDef((hd,), (None,), init="ones"),
        })
    return defs


def param_defs(cfg, tp: int = 1):
    return {
        **common.embed_defs(cfg),
        "layers": common.stack_layer_defs(block_defs(cfg, tp), cfg.n_layers),
    }


def _qkv(p, x, cfg, cos, sin, ctx: Ctx, hmask):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if hmask is not None:
        q = q * hmask[None, None, :, None]
    q = ctx.constrain(q, "batch", "seq", "tensor", None)
    return q, k, v


def _attn_out(p, o, ctx: Ctx, hmask):
    if hmask is not None:
        o = o * hmask[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return ctx.constrain(out, "batch", "seq", None)


def _block_train(cfg, ctx: Ctx, cos, sin, hmask, use_full_attn: bool):
    def fn(carry, xs):
        h, aux = carry
        (p,) = xs
        x = rms_norm(h, p["ln1"])
        q, k, v = _qkv(p, x, cfg, cos, sin, ctx, hmask)
        g = max(cfg.n_heads // cfg.n_kv_heads, 1)
        if use_full_attn:
            o = attn_full(q, k, v, group_size=g)
        else:
            o = attn_chunked(q, k, v, q_chunk=cfg.attn_chunk,
                             kv_chunk=cfg.attn_chunk, group_size=g, ctx=ctx)
        h = h + _attn_out(p, o, ctx, hmask)
        x = rms_norm(h, p["ln2"])
        h = h + ctx.constrain(gated_mlp(p, x, ctx), "batch", "seq", None)
        h = ctx.constrain(h, "batch", "seq", None)
        return (h, aux), None
    return fn


def forward(params, batch, cfg, ctx: Ctx = NOCTX, return_cache: bool = False,
            return_hidden: bool = False):
    tokens = batch["tokens"]
    h = common.embed_tokens(params, tokens, cfg, ctx)
    h = common.maybe_prepend_embeds(h, batch, ctx)
    B, S = h.shape[0], h.shape[1]
    pos = jnp.arange(S)
    cos, sin = rope_tables(pos[None, :], cfg.head_dim, cfg.rope_theta)
    tp = ctx.axis_size("tensor")
    hmask = common.head_mask(cfg, tp, h.dtype)
    use_full = S <= 2048

    if not return_cache:
        blk = _block_train(cfg, ctx, cos, sin, hmask, use_full)
        h, _, _ = common.scan_blocks(blk, h, (params["layers"],),
                                     remat=(cfg.remat == "block"))
        if return_hidden:
            return h
        return common.unembed(params, h, cfg, ctx)

    # prefill: also emit per-layer kv caches
    def blk(carry, xs):
        h, _ = carry
        (p,) = xs
        x = rms_norm(h, p["ln1"])
        q, k, v = _qkv(p, x, cfg, cos, sin, ctx, hmask)
        g = max(cfg.n_heads // cfg.n_kv_heads, 1)
        if use_full:
            o = attn_full(q, k, v, group_size=g)
        else:
            o = attn_chunked(q, k, v, q_chunk=cfg.attn_chunk,
                             kv_chunk=cfg.attn_chunk, group_size=g, ctx=ctx)
        h = h + _attn_out(p, o, ctx, hmask)
        x = rms_norm(h, p["ln2"])
        h = h + gated_mlp(p, x, ctx)
        h = ctx.constrain(h, "batch", "seq", None)
        k = ctx.constrain(k, "batch", "kv_seq", None, None)
        v = ctx.constrain(v, "batch", "kv_seq", None, None)
        return (h, None), (k, v)

    h, _, (kc, vc) = common.scan_blocks(blk, h, (params["layers"],))
    logits = common.unembed(params, h, cfg, ctx)
    return logits, {"k": kc, "v": vc,
                    "pos": jnp.full((), S - 1, jnp.int32)}


def cache_defs(cfg, B: int, S: int, tp: int = 1):
    hd, Hkv, L = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
    kv_ax = None  # decode caches shard their length axis, not heads
    return {
        "k": ParamDef((L, B, S, Hkv, hd),
                      ("layers", "batch", "kv_seq", kv_ax, None),
                      init="zeros"),
        "v": ParamDef((L, B, S, Hkv, hd),
                      ("layers", "batch", "kv_seq", kv_ax, None),
                      init="zeros"),
        "pos": ParamDef((), (), init="zeros"),
    }


def decode_step(params, cache, tokens, cfg, ctx: Ctx = NOCTX):
    """tokens (B,1); attends the full cache up to cache['pos'] + itself."""
    B = tokens.shape[0]
    h = common.embed_tokens(params, tokens, cfg, ctx)
    pos = cache["pos"] + 1                      # position of the new token
    cos, sin = rope_tables(jnp.full((B, 1), pos), cfg.head_dim,
                           cfg.rope_theta)
    tp = ctx.axis_size("tensor")
    hmask = common.head_mask(cfg, tp, h.dtype)

    def blk(carry, xs):
        h, _ = carry
        p, kc, vc = xs
        x = rms_norm(h, p["ln1"])
        q, k, v = _qkv(p, x, cfg, cos, sin, ctx, hmask)
        # attention reads the OLD cache + an explicit self-token term;
        # the cache write happens once, post-scan, fully aliased.
        o = attn_decode(q, kc, vc, pos, k_new=k, v_new=v, ctx=ctx,
                        group_size=max(cfg.n_heads // cfg.n_kv_heads, 1))
        h = h + _attn_out(p, o, ctx, hmask)
        x = rms_norm(h, p["ln2"])
        h = h + gated_mlp(p, x, ctx)
        return (h, None), (k, v)

    (h, _), (k_new, v_new) = jax.lax.scan(
        blk, (h, None), (params["layers"], cache["k"], cache["v"]))
    kc = update_cache(cache["k"], k_new, pos, ctx, seq_axis=2)
    vc = update_cache(cache["v"], v_new, pos, ctx, seq_axis=2)
    logits = common.unembed(params, h, cfg, ctx)
    return logits, {"k": kc, "v": vc, "pos": pos}
