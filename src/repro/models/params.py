"""Parameter definition/initialization with logical sharding axes.

Models declare parameters once as :class:`ParamDef` pytrees; from that single
source of truth we derive

* ``init_params``      — materialized arrays (smoke tests, real training),
* ``abstract_params``  — ShapeDtypeStructs (dry-run lowering, no allocation),
* ``launch.sharding.tree_specs`` — PartitionSpecs for pjit in/out shardings.

Layer-stacked parameters (scan-over-layers) carry a leading "layers" axis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | scaled
    fan_in: Optional[int] = None      # for scaled init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            fan = d.fan_in if d.fan_in else (d.shape[-2] if len(d.shape) >= 2
                                             else d.shape[-1])
            scale = 1.0 / math.sqrt(max(fan, 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def)


def param_count(defs) -> int:
    return sum(math.prod(d.shape)
               for d in jax.tree.leaves(defs, is_leaf=_is_def))
