"""Shared building blocks for the assigned architectures.

Everything is pure-functional JAX: params are pytrees (see models.params),
activations get logical sharding constraints through ``Ctx`` (a mesh+rules
handle; ``Ctx(None)`` makes every constraint a no-op so smoke tests run on
one CPU device untouched).

Attention comes in three schedules:
* ``attn_full``     — materialized scores; smoke tests / short sequences.
* ``attn_chunked``  — blockwise online-softmax (flash-style) over Q and KV
                      blocks; used by train/prefill so a 32k x 32k score
                      tensor never exists.
* ``attn_decode``   — one new token vs a length-S cache; the cache length
                      axis is sharded over the model axis at serving
                      (flash-decoding: GSPMD turns the softmax reductions
                      into psums over cache shards).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.launch import sharding as shd


@dataclasses.dataclass(frozen=True)
class Ctx:
    mesh: Optional[Mesh]
    rules: Optional[Dict] = None

    def constrain(self, x, *logical):
        if self.mesh is None:
            return x
        return shd.constrain(x, self.mesh, self.rules, *logical)

    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1
        ax = self.rules.get(logical)
        if ax is None:
            return 1
        axes = (ax,) if isinstance(ax, str) else ax
        n = 1
        for a in axes:
            if a in self.mesh.shape:
                n *= self.mesh.shape[a]
        return n


NOCTX = Ctx(None)


# ---------------------------------------------------------------------------
# norms / rope / mlp
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope_tables(positions, dim: int, theta: float):
    """positions (...,) -> cos/sin tables (..., dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., dim) with interleaved halves convention (x1 | x2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    while cos.ndim < x1.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def gated_mlp(params, x, ctx: "Ctx" = None):
    """SwiGLU: silu(x W_g) * (x W_u) W_d.

    The hidden (ff) axis is pinned to the tensor axis so GSPMD keeps the
    megatron schedule (col-parallel up, row-parallel down, one psum) instead
    of gathering weights (PERF: EXPERIMENTS.md Perf-1).
    """
    g = jnp.einsum("...d,df->...f", x, params["wg"])
    u = jnp.einsum("...d,df->...f", x, params["wu"])
    if ctx is not None:
        g = ctx.constrain(g, *(("batch",) + (None,) * (g.ndim - 2)
                               + ("tensor",)))
        u = ctx.constrain(u, *(("batch",) + (None,) * (u.ndim - 2)
                               + ("tensor",)))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["wd"])


# ---------------------------------------------------------------------------
# attention schedules (GQA)
# ---------------------------------------------------------------------------

def _expand_kv(k, n_q_heads: int, group_size: Optional[int] = None):
    """Map each query head to its GQA kv head.

    With TP head-padding the query head count may not be an exact multiple
    of the kv head count; the mapping ``kv = min(h // group_size, Hkv-1)``
    preserves the original model's groups exactly (padded heads are masked
    out downstream anyway).
    """
    Hkv = k.shape[2]
    if n_q_heads == Hkv:
        return k
    g = group_size or max(n_q_heads // Hkv, 1)
    idx = jnp.minimum(jnp.arange(n_q_heads) // g, Hkv - 1)
    return k[:, :, idx, :]


def attn_full(q, k, v, *, causal: bool = True, q_offset=0,
              group_size: Optional[int] = None):
    """(B,Sq,H,dh) x (B,Sk,Hkv,dh) -> (B,Sq,H,dh), materialized scores."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H, group_size)
    v = _expand_kv(v, H, group_size)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    if causal:
        qi = jnp.arange(Sq)[:, None] + q_offset
        ki = jnp.arange(Sk)[None, :]
        scores = jnp.where((ki <= qi)[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def attn_chunked(q, k, v, *, q_chunk: int = 512, kv_chunk: int = 512,
                 causal: bool = True, group_size: Optional[int] = None,
                 ctx: "Ctx" = None):
    """Blockwise online-softmax attention (no S x S tensor).

    The (expanded) KV blocks and the q blocks are pinned head-sharded over
    the tensor axis BEFORE the block scans; otherwise GSPMD reshards a KV
    block on every inner step — an all-to-all inside a doubly-nested loop
    dominated the whole prefill roofline (PERF: EXPERIMENTS.md Perf-1).
    """
    B, S, H, dh = q.shape
    dv = v.shape[-1]  # MLA: v head dim differs from q/k head dim
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    assert S % qc == 0 and S % kc == 0, (S, qc, kc)
    nq, nk = S // qc, S // kc
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    kr = _expand_kv(k, H, group_size).reshape(B, nk, kc, H, dh)
    vr = _expand_kv(v, H, group_size).reshape(B, nk, kc, H, dv)
    qs = q.reshape(B, nq, qc, H, dh)
    if ctx is not None:
        kr = ctx.constrain(kr, "batch", None, None, "tensor", None)
        vr = ctx.constrain(vr, "batch", None, None, "tensor", None)
        qs = ctx.constrain(qs, "batch", None, None, "tensor", None)

    def q_block(qi, qb, nk_eff: int):
        # online softmax over kv blocks
        def kv_step(carry, j):
            m, l, acc = carry
            kb = kr[:, j]  # (B, kc, H, dh)
            vb = vr[:, j]
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32)
            s = s * scale
            if causal:
                qpos = qi * qc + jnp.arange(qc)[:, None]
                kpos = j * kc + jnp.arange(kc)[None, :]
                s = jnp.where((kpos <= qpos)[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk_eff))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 2, 1, 3)  # (B, qc, H, dv)

    if not causal:
        outs = jax.lax.map(lambda i: q_block(i, qs[:, i], nk),
                           jnp.arange(nq))
        return outs.transpose(1, 0, 2, 3, 4).reshape(
            B, S, H, dv).astype(q.dtype)

    # causal triangular scheduling (PERF: EXPERIMENTS.md Perf-2): a single
    # full-length inner scan spends 2x the needed FLOPs on fully-masked
    # j > i blocks.  Bucket q blocks by prefix length; bucket b only scans
    # its own prefix — total block pairs drop from nq^2 toward nq^2/2.
    nb = min(8, nq)
    parts = []
    for b in range(nb):
        i0, i1 = b * nq // nb, (b + 1) * nq // nb
        if i0 == i1:
            continue
        nk_eff = max(1, (i1 * qc + kc - 1) // kc)  # prefix covering block i1-1
        sub = jax.lax.map(lambda i: q_block(i, qs[:, i], nk_eff),
                          jnp.arange(i0, i1))
        parts.append(sub)
    outs = jnp.concatenate(parts, axis=0)
    # (nq, B, qc, H, dv) -> (B, S, H, dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dv).astype(q.dtype)


def update_cache(cache, new, pos, ctx: Ctx = NOCTX, seq_axis: int = 1):
    """Write one decode step into a cache whose length axis may be sharded
    over the model axis.

    A plain dynamic_update_slice at a runtime offset on a sharded axis makes
    GSPMD materialize the *full* cache (all-gather, update, re-shard — tens
    of GiB for a 72B/32k cell).  Instead we shard_map the update: only the
    shard owning position ``pos`` touches memory, and only an O(new)-sized
    slice is ever temporary.  Call this ONCE per step on the layer-stacked
    cache (decode attention reads the *old* cache plus an explicit
    self-token term), so the donated input aliases the output and the scan
    never copies cache shards.

    cache: (..., S at seq_axis, ...); new: same with length 1; pos: int32.
    """
    zeros = (0,) * cache.ndim

    def plain():
        start = zeros[:seq_axis] + (pos,) + zeros[seq_axis + 1:]
        return jax.lax.dynamic_update_slice(
            cache, new.astype(cache.dtype), start)

    if ctx.mesh is None or "model" not in getattr(ctx.mesh, "shape", {}):
        return plain()
    kv_ax = ctx.rules.get("kv_seq") if ctx.rules else None
    if kv_ax is None:
        return plain()
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import _drop_missing, _fit_axes
    mesh = ctx.mesh
    # batch axis is whichever non-seq axis carries the batch sharding; we
    # conservatively shard only the seq axis here and let GSPMD reshard --
    # but keeping the batch sharding explicit avoids any data motion:
    specs = [None] * cache.ndim
    specs[seq_axis] = "model"
    # find a batch-sized axis to keep sharded (axis 0 for (B,S,..),
    # axis 1 for stacked (L,B,S,..))
    b_axis = 0 if seq_axis == 1 else 1
    batch_ax = _fit_axes(cache.shape[b_axis], _drop_missing(
        ctx.rules["batch"], mesh), mesh)
    specs[b_axis] = batch_ax
    cspec = P(*specs)
    nspecs = list(specs)
    nspecs[seq_axis] = None
    nspec = P(*nspecs)

    def upd(c_local, n_local, p):
        i = jax.lax.axis_index("model")
        local_s = c_local.shape[seq_axis]
        off = p - i * local_s
        inb = (off >= 0) & (off < local_s)
        off_c = jnp.clip(off, 0, local_s - 1)
        start = zeros[:seq_axis] + (off_c,) + zeros[seq_axis + 1:]
        cur = jax.lax.dynamic_slice(c_local, start, n_local.shape)
        val = jnp.where(inb, n_local.astype(c_local.dtype), cur)
        return jax.lax.dynamic_update_slice(c_local, val, start)

    return shard_map(upd, mesh=mesh, in_specs=(cspec, nspec, P()),
                     out_specs=cspec, check_rep=False)(
        cache, new, jnp.asarray(pos, jnp.int32))


def attn_decode(q, k_cache, v_cache, pos, k_new=None, v_new=None,
                ctx: Ctx = NOCTX, group_size: Optional[int] = None):
    """One-step attention: q (B,1,H,dh) vs the OLD cache (B,S,Hkv,dh) plus
    the new token's own k/v (B,1,Hkv,dh) as an explicit extra term.

    Cache entries at positions >= pos (the new token's position) are
    masked.  Reading the old cache (instead of the freshly-updated one)
    removes the data dependence between attention and the cache write, so
    the write happens once per step on the layer-stacked cache with full
    input/output aliasing — no per-layer cache copies in the scan.
    """
    B, _, H, dh = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    if group_size and H == Hkv * group_size:
        # grouped form: never materialize the expanded KV — each GQA group
        # contracts directly against its kv head, so the cache is read once
        # (decode is memory-bound; an 8x expansion would be 8x HBM traffic)
        g = group_size
        qg = q.reshape(B, 1, Hkv, g, dh)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache)
        s = s.astype(jnp.float32) * scale
        s = ctx.constrain(s, "batch", None, None, None, "kv_seq")
        mask = (jnp.arange(S)[None, None, None, None, :] < pos)
        s = jnp.where(mask, s, -1e30)
        if k_new is not None:
            s_self = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_new)
            s_self = s_self.astype(jnp.float32) * scale
            # no concat: concatenating the (.,.,.,1,S) and (.,.,.,1,1)
            # score blocks forces GSPMD to reshard the big block
            m = jnp.maximum(s.max(-1, keepdims=True),
                            s_self.max(-1, keepdims=True))
            p_c = jnp.exp(s - m)
            p_s = jnp.exp(s_self - m)
            denom = p_c.sum(-1, keepdims=True) + p_s.sum(-1, keepdims=True)
            out = jnp.einsum("bkgqs,bskd->bqkgd",
                             (p_c / denom).astype(v_cache.dtype), v_cache)
            out = out + jnp.einsum(
                "bkgqs,bskd->bqkgd", (p_s / denom).astype(v_new.dtype), v_new)
            return out.reshape(B, 1, H, dh)
        w = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v_cache.dtype),
                         v_cache)
        return out.reshape(B, 1, H, dh)
    k = _expand_kv(k_cache, H, group_size)
    v = _expand_kv(v_cache, H, group_size)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores * scale
    scores = ctx.constrain(scores, "batch", None, None, "kv_seq")
    mask = (jnp.arange(S)[None, None, None, :] < pos)
    scores = jnp.where(mask, scores, -1e30)
    if k_new is not None:
        kn = _expand_kv(k_new, H, group_size)
        vn = _expand_kv(v_new, H, group_size)
        s_self = jnp.einsum("bqhd,bkhd->bhqk", q, kn).astype(jnp.float32)
        s_self = s_self * scale
        m = jnp.maximum(scores.max(-1, keepdims=True),
                        s_self.max(-1, keepdims=True))
        p_c = jnp.exp(scores - m)
        p_s = jnp.exp(s_self - m)
        denom = p_c.sum(-1, keepdims=True) + p_s.sum(-1, keepdims=True)
        out = jnp.einsum("bhqk,bkhd->bqhd", (p_c / denom).astype(v.dtype), v)
        out = out + jnp.einsum("bhqk,bkhd->bqhd",
                               (p_s / denom).astype(vn.dtype), vn)
        return out
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# MoE block (token-choice top-k, capacity dispatch, EP over the model axis)
# ---------------------------------------------------------------------------

def moe_router(x, wr, top_k: int):
    """x (T,d), wr (d,E) -> (gates (T,k), idx (T,k), aux_loss)."""
    logits = jnp.einsum("td,de->te", x, wr).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style)
    E = wr.shape[1]
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / idx.size)
    aux = E * jnp.sum(me * ce)
    return gates.astype(x.dtype), idx, aux


def moe_expert_compute(x_flat, gates, idx, w_gate, w_up, w_down, *,
                       n_experts: int, expert_offset, capacity: int):
    """Capacity-based dispatch for the local expert slice.

    x_flat (T,d); idx (T,k) global expert ids; w_* (E_loc, ...) local
    experts.  Returns (T,d) partial output (sum over *local* experts only —
    caller psums over the expert-parallel axis).
    """
    T, d = x_flat.shape
    E_loc = w_gate.shape[0]
    k = idx.shape[1]
    flat_e = idx.reshape(-1) - expert_offset              # (T*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    mine = (flat_e >= 0) & (flat_e < E_loc)
    e_safe = jnp.where(mine, flat_e, 0)
    onehot = jax.nn.one_hot(e_safe, E_loc, dtype=jnp.int32) * mine[:, None]
    ranks = jnp.cumsum(onehot, axis=0) - 1                # (T*k, E_loc)
    rank = jnp.sum(ranks * onehot, axis=1)                # rank within expert
    keep = mine & (rank < capacity)
    # dispatch buffers (E_loc, capacity): token index + gate (0 where empty)
    slot_e = jnp.where(keep, e_safe, 0)
    slot_r = jnp.where(keep, rank, capacity)              # dump row
    buf_t = jnp.zeros((E_loc, capacity + 1), jnp.int32).at[
        slot_e, slot_r].set(jnp.where(keep, flat_t + 1, 0))[:, :capacity]
    buf_g = jnp.zeros((E_loc, capacity + 1), flat_g.dtype).at[
        slot_e, slot_r].set(jnp.where(keep, flat_g, 0.0))[:, :capacity]
    occupied = buf_t > 0
    xg = x_flat[jnp.maximum(buf_t - 1, 0)]                # (E_loc, C, d)
    xg = xg * occupied[..., None].astype(xg.dtype)
    g = jnp.einsum("ecd,edf->ecf", xg, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xg, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xg.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, w_down)
    y = y * buf_g[..., None].astype(y.dtype)
    out = jnp.zeros((T + 1, d), y.dtype).at[buf_t.reshape(-1)].add(
        y.reshape(-1, d))[1:]
    return out


def moe_block(params, x, cfg, ctx: Ctx = NOCTX):
    """Full MoE layer: shared experts (dense) + routed experts (EP).

    x (B, S, d).  Routed experts are sharded over the "experts" logical axis
    (the model mesh axis); with a mesh this runs under shard_map so dispatch
    is local per shard and a single psum combines expert partials.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    def local(x_l, wr, wg, wu, wd, idx_shift):
        T = x_l.shape[0] * x_l.shape[1]
        xf = x_l.reshape(T, d)
        gates, idx, aux = moe_router(xf, wr, k)
        cap = max(8, int(T * k * cfg.capacity_factor) // E)
        out = moe_expert_compute(
            xf, gates, idx, wg, wu, wd,
            n_experts=E, expert_offset=idx_shift, capacity=cap)
        return out.reshape(x_l.shape), aux

    if ctx.mesh is not None and "model" in ctx.mesh.shape:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.launch.sharding import _drop_missing, _fit_axes
        mesh = ctx.mesh
        batch_ax = _fit_axes(x.shape[0], _drop_missing(
            ctx.rules["batch"], mesh), mesh)
        xspec = P(batch_ax, None, None)
        espec = P("model", None, None)

        def mapped(x_l, wr, wg, wu, wd):
            eloc = wg.shape[0]
            shift = jax.lax.axis_index("model") * eloc
            out, aux = local(x_l, wr, wg, wu, wd, shift)
            out = jax.lax.psum(out, "model")
            aux = jax.lax.pmean(aux, "model")
            return out, aux

        out, aux = shard_map(
            mapped, mesh=mesh,
            in_specs=(xspec, P(None, None), espec, espec, espec),
            out_specs=(xspec, P()),
            check_rep=False,
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])
    else:
        out, aux = local(x, params["router"], params["w_gate"],
                         params["w_up"], params["w_down"], 0)
    if cfg.n_shared_experts:
        out = out + gated_mlp(params["shared"], x)
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) — chunked scan + single-step recurrence
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int):
    """Chunked state-space-duality scan (Mamba2).

    x (B,S,H,P), dt (B,S,H) (post-softplus), A (H,) (negative),
    Bm/Cm (B,S,G,N), D (H,).  Returns y (B,S,H,P) and final state
    (B,H,P,N).
    """
    Bsz, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    c = min(chunk, S)
    assert S % c == 0
    nc = S // c
    xs = x.reshape(Bsz, nc, c, H, Pd)
    dts = dt.reshape(Bsz, nc, c, H)
    Bs = jnp.repeat(Bm.reshape(Bsz, nc, c, G, N), rep, axis=3)
    Cs = jnp.repeat(Cm.reshape(Bsz, nc, c, G, N), rep, axis=3)

    dA = dts * A[None, None, :]                      # (B,k,c,H) negative
    cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum
    seg_end = cum[:, :, -1, :]                       # total chunk decay

    # intra-chunk (quadratic in c): y_intra[t] = sum_{s<=t} C_t.B_s decay x_s
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(tri[None, None, :, :, None], decay, 0.0)  # (B,k,c,s,H)
    cb = jnp.einsum("bkchn,bkshn->bkhcs", Cs, Bs).astype(jnp.float32)
    att = cb * decay.transpose(0, 1, 4, 2, 3)             # (B,k,H,c,s)
    xdt = (xs * dts[..., None]).astype(jnp.float32)       # (B,k,c,H,P)
    y_intra = jnp.einsum("bkhcs,bkshp->bkchp", att, xdt)

    # contribution of each chunk to its own end-state
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cum)  # (B,k,c,H)
    state_in = jnp.einsum("bkchn,bkchp->bkhpn", Bs,
                          xdt * decay_to_end[..., None])

    # inter-chunk recurrence over chunks
    def step(h, inp):
        st_in, dec = inp
        h_new = h * jnp.exp(dec)[:, :, None, None] + st_in
        return h_new, h

    h0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32)
    hT, h_prev = jax.lax.scan(
        step, h0,
        (state_in.transpose(1, 0, 2, 3, 4), seg_end.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)              # (B,k,H,P,N)

    # inter-chunk output: C_t . (decay from chunk start) . h_prev
    dec_from_start = jnp.exp(cum)                         # (B,k,c,H)
    y_inter = jnp.einsum("bkchn,bkhpn->bkchp", Cs,
                         h_prev.astype(jnp.float32)) \
        * dec_from_start[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), hT


def ssd_step(x, dt, A, Bm, Cm, D, h):
    """Single decode step: x (B,H,P), dt (B,H), Bm/Cm (B,G,N), h (B,H,P,N)."""
    G = Bm.shape[1]
    rep = x.shape[1] // G
    Bs = jnp.repeat(Bm, rep, axis=1)                     # (B,H,N)
    Cs = jnp.repeat(Cm, rep, axis=1)
    dA = jnp.exp(dt * A[None, :])[..., None, None]       # (B,H,1,1)
    upd = jnp.einsum("bhn,bhp->bhpn", Bs, (x * dt[..., None]))
    h_new = h * dA + upd
    y = jnp.einsum("bhn,bhpn->bhp", Cs, h_new.astype(Cs.dtype))
    y = y + x * D[None, :, None]
    return y.astype(x.dtype), h_new


def causal_conv1d(x, w, cache=None):
    """Depthwise causal conv: x (B,S,C), w (K,C).  With a cache (B,K-1,C),
    performs the streaming update and returns (y, new_cache)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([cache, x], axis=1)
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    new_cache = pad[:, -(K - 1):, :] if K > 1 else pad[:, :0, :]
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype), new_cache
