"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared attention+MLP
block applied every ``attn_every`` SSM blocks (weight re-use across
applications — the Zamba trick).  Each application keeps its own KV cache
at decode; SSM layers keep O(1) state, so long-context decode stays
sub-quadratic (per-token cost O(n_app * S) attention reads, no S^2 term).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common, transformer
from repro.models.layers import (Ctx, NOCTX, apply_rope, attn_chunked,
                                 attn_decode, attn_full, gated_mlp, rms_norm,
                                 rope_tables, update_cache)
from repro.models.mamba2 import block_defs as ssm_block_defs
from repro.models.mamba2 import ssm_block
from repro.models.params import ParamDef


def n_applications(cfg) -> int:
    return cfg.n_layers // cfg.attn_every


def param_defs(cfg, tp: int = 1):
    return {
        **common.embed_defs(cfg),
        "layers": common.stack_layer_defs(ssm_block_defs(cfg, tp),
                                          cfg.n_layers),
        "shared": transformer.block_defs(cfg, tp),   # ONE shared attn block
    }


def _shared_block(p, h, cfg, ctx, cos, sin, hmask, kc=None, vc=None,
                  pos=None, want_cache=False):
    """The shared attention+MLP block (transformer semantics)."""
    x = rms_norm(h, p["ln1"])
    q, k, v = transformer._qkv(p, x, cfg, cos, sin, ctx, hmask)
    g = max(cfg.n_heads // cfg.n_kv_heads, 1)
    if kc is not None:  # decode: read old cache + explicit self-token term
        o = attn_decode(q, kc, vc, pos, k_new=k, v_new=v, ctx=ctx,
                        group_size=g)
    elif h.shape[1] <= 2048:
        o = attn_full(q, k, v, group_size=g)
    else:
        o = attn_chunked(q, k, v, q_chunk=cfg.attn_chunk,
                         kv_chunk=cfg.attn_chunk, group_size=g, ctx=ctx)
    h = h + transformer._attn_out(p, o, ctx, hmask)
    x = rms_norm(h, p["ln2"])
    h = h + ctx.constrain(gated_mlp(p, x, ctx), "batch", "seq", None)
    return h, (k, v)


def _groups(cfg):
    """Split layer indices into groups; the shared block runs after each
    complete group of ``attn_every`` SSM layers."""
    k = cfg.attn_every
    n = cfg.n_layers
    bounds = []
    start = 0
    while start < n:
        end = min(start + k, n)
        bounds.append((start, end, end - start == k))
        start = end
    return bounds


def forward(params, batch, cfg, ctx: Ctx = NOCTX, return_cache: bool = False,
            return_hidden: bool = False):
    h = common.embed_tokens(params, batch["tokens"], cfg, ctx)
    h = common.maybe_prepend_embeds(h, batch, ctx)
    S = h.shape[1]
    cos, sin = rope_tables(jnp.arange(S)[None, :], cfg.head_dim,
                           cfg.rope_theta)
    tp = ctx.axis_size("tensor")
    hmask = common.head_mask(cfg, tp, h.dtype)
    remat = (cfg.remat == "block") and not return_cache

    def blk(carry, xs):
        h, _ = carry
        (p,) = xs
        out, (conv, st) = ssm_block(p, h, cfg, ctx)
        ys = (conv, st) if return_cache else None
        return (ctx.constrain(h + out, "batch", "seq", None), None), ys

    kvs = []
    ssm_caches = []
    for (g0, g1, complete) in _groups(cfg):
        sub = jax.tree.map(lambda a: a[g0:g1], params["layers"])
        h, _, ys = common.scan_blocks(blk, h, (sub,), remat=remat)
        if return_cache:
            ssm_caches.append(ys)
        if complete:
            h, kv = _shared_block(params["shared"], h, cfg, ctx, cos, sin,
                                  hmask, want_cache=return_cache)
            if return_cache:
                kvs.append(kv)
    if return_hidden:
        return h
    logits = common.unembed(params, h, cfg, ctx)
    if not return_cache:
        return logits
    conv = jnp.concatenate([c for c, _ in ssm_caches], axis=0)
    st = jnp.concatenate([s for _, s in ssm_caches], axis=0)
    kc = jnp.stack([ctx.constrain(k, "batch", "kv_seq", None, None)
                    for k, _ in kvs])
    vc = jnp.stack([ctx.constrain(v, "batch", "kv_seq", None, None)
                    for _, v in kvs])
    return logits, {"conv": conv, "state": st, "k": kc, "v": vc,
                    "pos": jnp.full((), S - 1, jnp.int32)}


def cache_defs(cfg, B: int, S: int, tp: int = 1):
    from repro.models.mamba2 import cache_defs as ssm_cache_defs
    defs = ssm_cache_defs(cfg, B, S, tp)
    napp = n_applications(cfg)
    hd, Hkv = cfg.head_dim, cfg.n_kv_heads
    defs["k"] = ParamDef((napp, B, S, Hkv, hd),
                         (None, "batch", "kv_seq", None, None), init="zeros")
    defs["v"] = ParamDef((napp, B, S, Hkv, hd),
                         (None, "batch", "kv_seq", None, None), init="zeros")
    return defs


def decode_step(params, cache, tokens, cfg, ctx: Ctx = NOCTX):
    h = common.embed_tokens(params, tokens, cfg, ctx)
    pos = cache["pos"] + 1
    B = tokens.shape[0]
    cos, sin = rope_tables(jnp.full((B, 1), pos), cfg.head_dim,
                           cfg.rope_theta)
    tp = ctx.axis_size("tensor")
    hmask = common.head_mask(cfg, tp, h.dtype)

    def blk(carry, xs):
        h, _ = carry
        p, conv_c, st = xs
        out, (conv_c, st2) = ssm_block(p, h, cfg, ctx,
                                       conv_cache=conv_c, state=st)
        return (h + out, None), (conv_c, st2.astype(st.dtype))

    new_conv, new_state, new_k, new_v = [], [], [], []
    app = 0
    for (g0, g1, complete) in _groups(cfg):
        sub = jax.tree.map(lambda a: a[g0:g1], params["layers"])
        (h, _), (conv, st) = jax.lax.scan(
            blk, (h, None),
            (sub, cache["conv"][g0:g1], cache["state"][g0:g1]))
        new_conv.append(conv)
        new_state.append(st)
        if complete:
            h, (kc, vc) = _shared_block(
                params["shared"], h, cfg, ctx, cos, sin, hmask,
                kc=cache["k"][app], vc=cache["v"][app], pos=pos)
            new_k.append(kc)
            new_v.append(vc)
            app += 1
    logits = common.unembed(params, h, cfg, ctx)
    kc = update_cache(cache["k"], jnp.stack(new_k), pos, ctx, seq_axis=2)
    vc = update_cache(cache["v"], jnp.stack(new_v), pos, ctx, seq_axis=2)
    return logits, {
        "conv": jnp.concatenate(new_conv, 0),
        "state": jnp.concatenate(new_state, 0),
        "k": kc, "v": vc,
        "pos": pos,
    }
