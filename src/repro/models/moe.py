"""MoE decoder with Multi-head Latent Attention (DeepSeek-V2 / Kimi-K2).

MLA: queries optionally low-rank (q_lora); keys/values decompressed from a
shared compressed latent c_kv (kv_lora) plus a single shared RoPE key head.
The decode cache stores only (c_kv, k_rope) — the architecture's point —
and decoding uses the *absorbed* formulation (scores computed in latent
space, W_uk/W_uv folded into the query/output transforms).

MoE: token-choice top-k routing with capacity dispatch; routed experts are
expert-parallel over the model mesh axis (see layers.moe_block); shared
experts and the first ``first_dense_layers`` dense blocks run as plain TP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.layers import (Ctx, NOCTX, apply_rope, attn_chunked,
                                 attn_full, gated_mlp, moe_block, rms_norm,
                                 rope_tables, update_cache)
from repro.models.params import ParamDef


def mla_defs(cfg, tp: int = 1):
    d = cfg.d_model
    H = cfg.heads_padded(tp)
    qh = cfg.nope_head_dim + cfg.rope_head_dim
    defs = {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "wo": ParamDef((H, cfg.v_head_dim, d), ("tensor", None, "embed"),
                       fan_in=H * cfg.v_head_dim),
        "wdkv": ParamDef((d, cfg.kv_lora), ("embed", None), fan_in=d),
        "kv_norm": ParamDef((cfg.kv_lora,), (None,), init="ones"),
        "wkr": ParamDef((d, cfg.rope_head_dim), ("embed", None), fan_in=d),
        "wuk": ParamDef((cfg.kv_lora, H, cfg.nope_head_dim),
                        (None, "tensor", None), fan_in=cfg.kv_lora),
        "wuv": ParamDef((cfg.kv_lora, H, cfg.v_head_dim),
                        (None, "tensor", None), fan_in=cfg.kv_lora),
    }
    if cfg.q_lora:
        defs.update({
            "wdq": ParamDef((d, cfg.q_lora), ("embed", None), fan_in=d),
            "q_norm": ParamDef((cfg.q_lora,), (None,), init="ones"),
            "wuq": ParamDef((cfg.q_lora, H, qh), (None, "tensor", None),
                            fan_in=cfg.q_lora),
        })
    else:
        defs["wq"] = ParamDef((d, H, qh), ("embed", "tensor", None), fan_in=d)
    return defs


def dense_mlp_defs(cfg):
    d = cfg.d_model
    return {
        "ln2": ParamDef((d,), (None,), init="ones"),
        "wg": ParamDef((d, cfg.d_ff), ("embed", "tensor"), fan_in=d),
        "wu": ParamDef((d, cfg.d_ff), ("embed", "tensor"), fan_in=d),
        "wd": ParamDef((cfg.d_ff, d), ("tensor", "embed"), fan_in=cfg.d_ff),
    }


def moe_mlp_defs(cfg):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    fs = f * cfg.n_shared_experts
    defs = {
        "ln2": ParamDef((d,), (None,), init="ones"),
        "router": ParamDef((d, E), (None, None), fan_in=d),
        "w_gate": ParamDef((E, d, f), ("experts", "embed", None), fan_in=d),
        "w_up": ParamDef((E, d, f), ("experts", "embed", None), fan_in=d),
        "w_down": ParamDef((E, f, d), ("experts", None, "embed"), fan_in=f),
    }
    if cfg.n_shared_experts:
        defs["shared"] = {
            "wg": ParamDef((d, fs), ("embed", "tensor"), fan_in=d),
            "wu": ParamDef((d, fs), ("embed", "tensor"), fan_in=d),
            "wd": ParamDef((fs, d), ("tensor", "embed"), fan_in=fs),
        }
    return defs


def param_defs(cfg, tp: int = 1):
    nd = cfg.first_dense_layers
    defs = {
        **common.embed_defs(cfg),
        "moe_layers": common.stack_layer_defs(
            {**mla_defs(cfg, tp), **moe_mlp_defs(cfg)}, cfg.n_layers - nd),
    }
    if nd > 0:
        defs["dense_layers"] = common.stack_layer_defs(
            {**mla_defs(cfg, tp), **dense_mlp_defs(cfg)}, nd)
    return defs


def _mla_qkv(p, x, cfg, cos, sin, ctx: Ctx, hmask):
    """Full (decompressed) MLA q/k/v for train/prefill."""
    nope, rope_d = cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, cos, sin)

    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"]), p["kv_norm"])
    k_rope = apply_rope(jnp.einsum("bsd,dk->bsk", x, p["wkr"])[:, :, None, :],
                        cos, sin)                      # (B,S,1,rope_d)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhv->bshv", ckv, p["wuv"])
    H = q.shape[2]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (rope_d,))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    if hmask is not None:
        q = q * hmask[None, None, :, None]
    q = ctx.constrain(q, "batch", "seq", "tensor", None)
    return q, k, v, ckv, k_rope[:, :, 0, :]


def _attn_out(p, o, ctx: Ctx, hmask):
    if hmask is not None:
        o = o * hmask[None, None, :, None]
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return ctx.constrain(out, "batch", "seq", None)


def _mla_block(p, h, cfg, cos, sin, ctx, hmask, use_full, want_cache):
    x = rms_norm(h, p["ln1"])
    q, k, v, ckv, krope = _mla_qkv(p, x, cfg, cos, sin, ctx, hmask)
    if use_full:
        o = attn_full(q, k, v)
    else:
        o = attn_chunked(q, k, v, q_chunk=cfg.attn_chunk,
                         kv_chunk=cfg.attn_chunk, ctx=ctx)
    h = h + _attn_out(p, o, ctx, hmask)
    cache = None
    if want_cache:
        cache = (ctx.constrain(ckv, "batch", "kv_seq", None),
                 ctx.constrain(krope, "batch", "kv_seq", None))
    return h, cache


def forward(params, batch, cfg, ctx: Ctx = NOCTX, return_cache: bool = False,
            return_hidden: bool = False):
    tokens = batch["tokens"]
    h = common.embed_tokens(params, tokens, cfg, ctx)
    h = common.maybe_prepend_embeds(h, batch, ctx)
    S = h.shape[1]
    cos, sin = rope_tables(jnp.arange(S)[None, :], cfg.rope_head_dim,
                           cfg.rope_theta)
    tp = ctx.axis_size("tensor")
    hmask = common.head_mask(cfg, tp, h.dtype)
    use_full = S <= 2048
    nd = cfg.first_dense_layers
    caches = {"dense": None, "moe": None}

    def dense_blk(carry, xs):
        h, aux = carry
        (p,) = xs
        h, cache = _mla_block(p, h, cfg, cos, sin, ctx, hmask, use_full,
                              return_cache)
        x = rms_norm(h, p["ln2"])
        h = h + ctx.constrain(gated_mlp(p, x, ctx), "batch", "seq", None)
        return (h, aux), cache

    def moe_blk(carry, xs):
        h, aux = carry
        (p,) = xs
        h, cache = _mla_block(p, h, cfg, cos, sin, ctx, hmask, use_full,
                              return_cache)
        x = rms_norm(h, p["ln2"])
        mo, a = moe_block(p, x, cfg, ctx)
        h = h + ctx.constrain(mo, "batch", "seq", None)
        return (h, aux + a), cache

    remat = (cfg.remat == "block") and not return_cache
    aux = jnp.zeros((), jnp.float32)
    if nd > 0:
        h, aux, caches["dense"] = common.scan_blocks(
            dense_blk, h, (params["dense_layers"],), remat=remat,
            carry_extra=aux)
    h, aux, caches["moe"] = common.scan_blocks(
        moe_blk, h, (params["moe_layers"],), remat=remat, carry_extra=aux)
    if return_hidden:
        return h
    logits = common.unembed(params, h, cfg, ctx)
    if not return_cache:
        return logits, aux
    cache = {
        "dense_ckv": caches["dense"][0] if nd else None,
        "dense_kr": caches["dense"][1] if nd else None,
        "moe_ckv": caches["moe"][0],
        "moe_kr": caches["moe"][1],
        "pos": jnp.full((), S - 1, jnp.int32),
    }
    return logits, aux, cache


def cache_defs(cfg, B: int, S: int, tp: int = 1):
    nd, L = cfg.first_dense_layers, cfg.n_layers
    r, kr = cfg.kv_lora, cfg.rope_head_dim
    def c(n, dim):
        return ParamDef((n, B, S, dim), ("layers", "batch", "kv_seq", None),
                        init="zeros")
    defs = {
        "moe_ckv": c(L - nd, r), "moe_kr": c(L - nd, kr),
        "pos": ParamDef((), (), init="zeros"),
    }
    defs["dense_ckv"] = c(nd, r) if nd else None
    defs["dense_kr"] = c(nd, kr) if nd else None
    return defs


def _mla_decode_attn(p, x, ckv_c, kr_c, pos, cfg, ctx: Ctx, hmask, cos, sin):
    """Absorbed-MLA decode: scores and context in latent space.

    Reads the OLD latent cache plus an explicit self-token term; returns the
    new token's latents for the post-scan stacked cache write.
    """
    nope, rope_d = cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora:
        cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"])
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, cos, sin)
    if hmask is not None:
        q_nope = q_nope * hmask[None, None, :, None]
        q_rope = q_rope * hmask[None, None, :, None]

    # new token's latent kv
    ckv_new = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"]), p["kv_norm"])
    kr_new = apply_rope(jnp.einsum("bsd,dk->bsk", x, p["wkr"])[:, :, None, :],
                        cos, sin)[:, :, 0, :]

    # absorbed scores: q_nope -> latent space once per step
    scale = 1.0 / jnp.sqrt(jnp.float32(nope + rope_d))
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])
    s = jnp.einsum("bshr,btr->bhst", q_lat, ckv_c) \
        + jnp.einsum("bshk,btk->bhst", q_rope, kr_c)
    s = s.astype(jnp.float32) * scale
    s = ctx.constrain(s, "batch", None, None, "kv_seq")
    S = ckv_c.shape[1]
    mask = jnp.arange(S)[None, None, None, :] < pos
    s = jnp.where(mask, s, -1e30)
    s_self = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_new)
              + jnp.einsum("bshk,btk->bhst", q_rope, kr_new)
              ).astype(jnp.float32) * scale
    m = jnp.maximum(s.max(-1, keepdims=True), s_self.max(-1, keepdims=True))
    p_c = jnp.exp(s - m)
    p_s = jnp.exp(s_self - m)
    denom = p_c.sum(-1, keepdims=True) + p_s.sum(-1, keepdims=True)
    ctx_lat = jnp.einsum("bhst,btr->bshr",
                         (p_c / denom).astype(ckv_c.dtype), ckv_c)
    ctx_lat = ctx_lat + jnp.einsum(
        "bhst,btr->bshr", (p_s / denom).astype(ckv_new.dtype), ckv_new)
    o = jnp.einsum("bshr,rhv->bshv", ctx_lat, p["wuv"])
    return o, ckv_new, kr_new


def decode_step(params, cache, tokens, cfg, ctx: Ctx = NOCTX):
    B = tokens.shape[0]
    h = common.embed_tokens(params, tokens, cfg, ctx)
    pos = cache["pos"] + 1
    cos, sin = rope_tables(jnp.full((B, 1), pos), cfg.rope_head_dim,
                           cfg.rope_theta)
    tp = ctx.axis_size("tensor")
    hmask = common.head_mask(cfg, tp, h.dtype)
    nd = cfg.first_dense_layers

    def dense_blk(carry, xs):
        h, _ = carry
        p, ckv_c, kr_c = xs
        x = rms_norm(h, p["ln1"])
        o, ckv_new, kr_new = _mla_decode_attn(p, x, ckv_c, kr_c, pos, cfg,
                                              ctx, hmask, cos, sin)
        h = h + _attn_out(p, o, ctx, hmask)
        x = rms_norm(h, p["ln2"])
        h = h + gated_mlp(p, x)
        return (h, None), (ckv_new, kr_new)

    def moe_blk(carry, xs):
        h, _ = carry
        p, ckv_c, kr_c = xs
        x = rms_norm(h, p["ln1"])
        o, ckv_new, kr_new = _mla_decode_attn(p, x, ckv_c, kr_c, pos, cfg,
                                              ctx, hmask, cos, sin)
        h = h + _attn_out(p, o, ctx, hmask)
        x = rms_norm(h, p["ln2"])
        mo, _ = moe_block(p, x, cfg, ctx)
        h = h + mo
        return (h, None), (ckv_new, kr_new)

    new_cache = dict(cache)
    if nd:
        (h, _), (dc, dk) = jax.lax.scan(
            dense_blk, (h, None),
            (params["dense_layers"], cache["dense_ckv"], cache["dense_kr"]))
        new_cache["dense_ckv"] = update_cache(cache["dense_ckv"], dc, pos,
                                              ctx, seq_axis=2)
        new_cache["dense_kr"] = update_cache(cache["dense_kr"], dk, pos,
                                             ctx, seq_axis=2)
    (h, _), (mc, mk) = jax.lax.scan(
        moe_blk, (h, None),
        (params["moe_layers"], cache["moe_ckv"], cache["moe_kr"]))
    new_cache["moe_ckv"] = update_cache(cache["moe_ckv"], mc, pos, ctx,
                                        seq_axis=2)
    new_cache["moe_kr"] = update_cache(cache["moe_kr"], mk, pos, ctx,
                                       seq_axis=2)
    new_cache["pos"] = pos
    logits = common.unembed(params, h, cfg, ctx)
    return logits, new_cache
