"""Mamba2 (state-space duality) decoder — attention-free.

Each block: in_proj -> (z | x | B | C | dt), causal depthwise conv over
(x|B|C), softplus dt, chunked SSD scan (layers.ssd_chunked), gated RMSNorm,
out_proj.  Decode keeps O(1) state per layer: the SSM state (B,H,P,N) plus
the (K-1)-step conv window — this is what makes the 500k-context decode cell
trivially sub-quadratic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.layers import (Ctx, NOCTX, causal_conv1d, rms_norm,
                                 ssd_chunked, ssd_step)
from repro.models.params import ParamDef


def block_defs(cfg, tp: int = 1):
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * G * N
    return {
        "ln": ParamDef((d,), (None,), init="ones"),
        "w_in": ParamDef((d, 2 * di + 2 * G * N + H), ("embed", "tensor"),
                         fan_in=d),
        "conv_w": ParamDef((cfg.ssm_conv, conv_ch), (None, "tensor")),
        "A_log": ParamDef((H,), ("tensor",), init="zeros"),
        "D": ParamDef((H,), ("tensor",), init="ones"),
        "dt_bias": ParamDef((H,), ("tensor",), init="zeros"),
        "out_norm": ParamDef((di,), ("tensor",), init="ones"),
        "w_out": ParamDef((di, d), ("tensor", "embed"), fan_in=di),
    }


def param_defs(cfg, tp: int = 1):
    return {
        **common.embed_defs(cfg),
        "layers": common.stack_layer_defs(block_defs(cfg, tp), cfg.n_layers),
    }


def _split_proj(proj, cfg):
    di = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    Bm = proj[..., 2 * di:2 * di + G * N]
    Cm = proj[..., 2 * di + G * N:2 * di + 2 * G * N]
    dt = proj[..., 2 * di + 2 * G * N:]
    return z, x, Bm, Cm, dt


def ssm_block(p, h, cfg, ctx: Ctx, conv_cache=None, state=None):
    """Returns (out, (new_conv_cache, new_state)); caches None for train."""
    Bsz, S, _ = h.shape
    di = cfg.d_inner
    G, N, H, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xn = rms_norm(h, p["ln"])
    proj = jnp.einsum("bsd,dk->bsk", xn, p["w_in"])
    proj = ctx.constrain(proj, "batch", "seq", "tensor")
    z, x, Bm, Cm, dtr = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)
    conv_out, new_conv = causal_conv1d(conv_in, p["conv_w"], conv_cache)
    x, Bm, Cm = (conv_out[..., :di],
                 conv_out[..., di:di + G * N],
                 conv_out[..., di + G * N:])
    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(Bsz, S, H, P)
    Bh = Bm.reshape(Bsz, S, G, N)
    Ch = Cm.reshape(Bsz, S, G, N)
    if state is None:
        # pad S to a chunk multiple; padded steps have dt = 0 (identity
        # decay, zero input) so the state is unaffected
        c = min(cfg.ssm_chunk, S)
        pad = (-S) % c
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, new_state = ssd_chunked(xh, dt, A, Bh, Ch,
                                   p["D"].astype(jnp.float32), chunk=c)
        if pad:
            y = y[:, :S]
    else:
        y, new_state = ssd_step(xh[:, 0], dt[:, 0], A, Bh[:, 0], Ch[:, 0],
                                p["D"].astype(jnp.float32), state)
        y = y[:, None]
    y = y.reshape(Bsz, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["out_norm"])
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    return ctx.constrain(out, "batch", "seq", None), (new_conv, new_state)


def forward(params, batch, cfg, ctx: Ctx = NOCTX, return_cache: bool = False,
            return_hidden: bool = False):
    h = common.embed_tokens(params, batch["tokens"], cfg, ctx)
    h = common.maybe_prepend_embeds(h, batch, ctx)

    def blk(carry, xs):
        h, _ = carry
        (p,) = xs
        out, (conv, st) = ssm_block(p, h, cfg, ctx)
        h = ctx.constrain(h + out, "batch", "seq", None)
        ys = (conv, st) if return_cache else None
        return (h, None), ys

    h, _, ys = common.scan_blocks(
        blk, h, (params["layers"],),
        remat=(cfg.remat == "block") and not return_cache)
    if return_hidden:
        return h
    logits = common.unembed(params, h, cfg, ctx)
    if not return_cache:
        return logits
    conv, st = ys
    return logits, {"conv": conv, "state": st,
                    "pos": jnp.full((), h.shape[1] - 1, jnp.int32)}


def cache_defs(cfg, B: int, S: int, tp: int = 1):
    """Decode cache is O(1) in S: conv window + SSM state per layer."""
    L = cfg.n_layers
    G, N, H, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * G * N
    return {
        "conv": ParamDef((L, B, cfg.ssm_conv - 1, conv_ch),
                         ("layers", "batch", None, "tensor"), init="zeros"),
        "state": ParamDef((L, B, H, P, N),
                          ("layers", "batch", "tensor", None, None),
                          init="zeros"),
        "pos": ParamDef((), (), init="zeros"),
    }


def decode_step(params, cache, tokens, cfg, ctx: Ctx = NOCTX):
    h = common.embed_tokens(params, tokens, cfg, ctx)
    pos = cache["pos"] + 1

    def blk(carry, xs):
        h, _ = carry
        p, conv_c, st = xs
        out, (conv_c, st) = ssm_block(p, h, cfg, ctx,
                                      conv_cache=conv_c, state=st)
        return (h + out, None), (conv_c, st.astype(xs[2].dtype))

    (h, _), (conv, st) = jax.lax.scan(
        blk, (h, None), (params["layers"], cache["conv"], cache["state"]))
    logits = common.unembed(params, h, cfg, ctx)
    return logits, {"conv": conv, "state": st, "pos": pos}
