"""Architecture registry: ``--arch <id>`` -> (ModelConfig, model module)."""

from __future__ import annotations

import importlib
from typing import Tuple

ARCHS = {
    "zamba2-1.2b": ("repro.configs.zamba2_1p2b", "repro.models.hybrid"),
    "kimi-k2-1t-a32b": ("repro.configs.kimi_k2_1t_a32b", "repro.models.moe"),
    "deepseek-v2-236b": ("repro.configs.deepseek_v2_236b", "repro.models.moe"),
    "qwen3-4b": ("repro.configs.qwen3_4b", "repro.models.transformer"),
    "qwen2-72b": ("repro.configs.qwen2_72b", "repro.models.transformer"),
    "qwen2.5-32b": ("repro.configs.qwen2p5_32b", "repro.models.transformer"),
    "smollm-360m": ("repro.configs.smollm_360m", "repro.models.transformer"),
    "mamba2-370m": ("repro.configs.mamba2_370m", "repro.models.mamba2"),
    "musicgen-large": ("repro.configs.musicgen_large",
                       "repro.models.transformer"),
    "internvl2-76b": ("repro.configs.internvl2_76b",
                      "repro.models.transformer"),
}


def get(arch: str, reduced: bool = False) -> Tuple[object, object]:
    """Returns (config, model_module)."""
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    cfg_mod, model_mod = ARCHS[arch]
    cmod = importlib.import_module(cfg_mod)
    mmod = importlib.import_module(model_mod)
    cfg = cmod.reduced() if reduced else cmod.CONFIG
    return cfg, mmod


def names():
    return sorted(ARCHS)
