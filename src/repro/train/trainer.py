"""Preemption-safe training loop.

Fault-tolerance contract (scaled mentally to 1000+ nodes, exercised here on
one host):

* checkpoint every ``ckpt_every`` steps (async, atomic) + on preemption
  signal + on exit;
* resume-from-latest reproduces the exact data stream ((seed, step)-keyed
  batches) so a restarted job continues bit-compatibly modulo hardware
  nondeterminism;
* a ``failure_injector`` hook lets tests kill the loop at arbitrary steps
  and assert recovery;
* slow-step (straggler) detection logs and, in multi-controller
  deployments, would trigger the work-stealing path in serving — here it
  surfaces as metrics.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.data.pipeline import TokenBatcher
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import CheckpointManager
from repro.train.train_state import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, model, cfg, opt_cfg: opt_lib.OptConfig,
                 batcher: TokenBatcher, ckpt_dir, tcfg: TrainerConfig,
                 ctx=None, failure_injector: Optional[Callable] = None):
        from repro.models.layers import NOCTX
        self.model = model
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.batcher = batcher
        self.tcfg = tcfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=tcfg.keep_ckpts)
        self.step_fn = jax.jit(make_train_step(model, cfg, opt_cfg,
                                               ctx or NOCTX))
        self.failure_injector = failure_injector
        self._preempted = False
        self.metrics_log: List[Dict] = []

    def _handle_preemption(self, signum, frame):
        self._preempted = True

    def init_or_resume(self, rng_seed: int = 0):
        from repro.models.params import init_params
        import jax.numpy as jnp
        params = init_params(self.model.param_defs(self.cfg),
                             jax.random.PRNGKey(rng_seed), jnp.float32)
        opt_state = opt_lib.init_state(params, self.opt_cfg)
        start = 0
        if self.ckpt.latest_step() is not None:
            (params, opt_state), meta = self.ckpt.restore(
                (params, opt_state))
            start = meta["step"]
        return params, opt_state, start

    def run(self, rng_seed: int = 0) -> Dict:
        params, opt_state, start = self.init_or_resume(rng_seed)
        old = signal.signal(signal.SIGTERM, self._handle_preemption)
        durations: List[float] = []
        completed = start
        try:
            for step in range(start, self.tcfg.total_steps):
                if self.failure_injector is not None:
                    self.failure_injector(step)
                batch = self.batcher.batch_at(step)
                t0 = time.time()
                params, opt_state, m = self.step_fn(params, opt_state, batch)
                completed = step + 1
                dt = time.time() - t0
                durations.append(dt)
                med = float(np.median(durations[-50:]))
                straggler = dt > self.tcfg.straggler_factor * med \
                    and len(durations) > 5
                if step % self.tcfg.log_every == 0 or straggler:
                    self.metrics_log.append({
                        "step": step + 1,
                        "loss": float(m["loss"]),
                        "grad_norm": float(m["grad_norm"]),
                        "lr": float(m["lr"]),
                        "step_s": dt,
                        "straggler": bool(straggler),
                    })
                if completed % self.tcfg.ckpt_every == 0 or self._preempted:
                    self.ckpt.save(completed, (params, opt_state))
                if self._preempted:
                    break
        finally:
            # emergency/final checkpoint labels the COMPLETED step count,
            # so resume after a mid-step crash replays the failed step
            self.ckpt.save(completed, (params, opt_state), block=True)
            signal.signal(signal.SIGTERM, old)
        return {"params": params, "opt_state": opt_state,
                "final_step": completed, "log": self.metrics_log}
