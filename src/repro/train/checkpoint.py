"""Fault-tolerant checkpointing.

* atomic: write to a temp dir, fsync, rename — a crash mid-save never
  corrupts the latest checkpoint;
* ``latest`` pointer file for O(1) resume discovery;
* async mode: the device->host copy happens synchronously (cheap), the disk
  write runs on a background thread so training never stalls on I/O;
* retention: keep the last ``keep`` checkpoints;
* pytrees are stored as one .npz (path-flattened) + a metadata json.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None,
             block: bool = False) -> None:
        self.wait()  # never two writers (same-step saves must serialize)
        flat = _flatten(tree)  # device->host copy happens here, synchronously
        meta = {"step": int(step), "time": time.time(), **(extra or {})}
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat, meta) -> None:
        tmp = self.dir / f".tmp-{step}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "state.npz", **flat)
        (tmp / "meta.json").write_text(json.dumps(meta))
        with open(tmp / "state.npz", "rb") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        (self.dir / "latest.tmp").write_text(final.name)
        (self.dir / "latest.tmp").rename(self.dir / "latest")
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*"))
        for old in ckpts[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "latest"
        if not ptr.exists():
            return None
        name = ptr.read_text().strip()
        if not (self.dir / name).exists():
            # fall back to newest on-disk checkpoint
            ckpts = sorted(self.dir.glob("step_*"))
            if not ckpts:
                return None
            name = ckpts[-1].name
        return int(name.split("_")[1])

    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[Any, Dict[str, Any]]:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        with np.load(path / "state.npz") as z:
            flat = {k: z[k] for k in z.files}
        meta = json.loads((path / "meta.json").read_text())
        return _unflatten_into(template, flat), meta
