"""AdamW (pure JAX) with schedule, clipping, and optional gradient
compression — no optax dependency.

* decoupled weight decay, applied only to >=2D parameters (norms/bias
  excluded), standard LM practice;
* global-norm gradient clipping;
* warmup + cosine schedule;
* optimizer-state dtype is configurable: ``bf16`` moment states halve the
  optimizer memory of trillion-parameter models (the kimi-k2 cell does not
  fit 512 v5e chips with fp32 moments — see EXPERIMENTS.md §Dry-run);
* ``topk_compress``: error-feedback top-k gradient compression for slow
  interconnects (used by the trainer when ``grad_compression > 0``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"     # float32 | bfloat16
    grad_compression: float = 0.0    # 0 = off; else keep-fraction for top-k


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params, cfg: OptConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def apply_updates(params, grads, opt_state, cfg: OptConfig):
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


# -- gradient compression (error feedback top-k) ----------------------------

def topk_compress(grad, residual, keep_frac: float):
    """Error-feedback top-|g| sparsification of one gradient tensor.

    Returns (sparse_grad, new_residual).  The sparse gradient is dense-shaped
    with zeros off-support (TPU-friendly; the win is on the wire where
    all-reduce of mostly-zero blocks compresses, and in controlled staleness
    of small updates).  residual accumulates what was dropped.
    """
    g = grad.astype(jnp.float32) + residual.astype(jnp.float32)
    k = max(1, int(math.ceil(keep_frac * g.size)))
    flat = jnp.abs(g).reshape(-1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    mask = (jnp.abs(g) >= thresh).astype(jnp.float32)
    sparse = g * mask
    return sparse.astype(grad.dtype), (g - sparse).astype(residual.dtype)


def compress_tree(grads, residuals, keep_frac: float):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    outs = [topk_compress(g, r, keep_frac) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
