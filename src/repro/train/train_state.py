"""Train step factory: loss, grads, AdamW update — model-agnostic."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import Ctx, NOCTX
from repro.train import optimizer as opt_lib


def cross_entropy(logits, labels, mask=None):
    """Masked next-token CE.  labels < 0 are ignored.

    Implemented without take_along_axis: a gather along the (TP-sharded)
    vocab axis forces GSPMD to all-gather the full f32 logits; the
    iota-select keeps every op elementwise/reduce over the sharded axis
    (PERF: EXPERIMENTS.md Perf-3 — 13 GiB of temp on the smollm train cell
    came from one replicated f32 logits buffer).
    """
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape,
                                          lg.ndim - 1)
    sel = (vocab_iota == jnp.maximum(labels, 0)[..., None])
    gold = jnp.sum(jnp.where(sel, lg, 0.0), axis=-1)
    nll = lse - gold
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def make_loss_fn(model, cfg, ctx: Ctx = NOCTX, aux_weight: float = 0.01):
    def loss_fn(params, batch):
        out = model.forward(params, batch, cfg, ctx)
        if isinstance(out, tuple):
            logits, aux = out
        else:
            logits, aux = out, 0.0
        loss = cross_entropy(logits, batch["labels"])
        total = loss + aux_weight * aux
        return total, {"loss": loss, "aux_loss": aux}
    return loss_fn


def make_train_step(model, cfg, opt_cfg: opt_lib.OptConfig,
                    ctx: Ctx = NOCTX):
    loss_fn = make_loss_fn(model, cfg, ctx)

    def train_step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = opt_lib.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics = {**metrics, **om, "total_loss": total}
        return params, opt_state, metrics

    return train_step
