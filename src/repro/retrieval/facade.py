"""The unified retrieval facade: ONE entry point over every index and
execution engine.

::

    from repro.retrieval import RetrievalConfig, Retriever

    r = Retriever.build(RetrievalConfig("levenshtein", lam=16), seqs)
    rs = r.query(Q).range(2.0)          # type I   -> MatchPairs
    rs = r.query(Q).longest(2.0)        # type II  -> longest MatchPair
    rs = r.query(Q).nearest()           # type III -> nearest MatchPair
    rs = r.batch(queries).range(2.0)    # per-query hit lists

Three execution engines hide behind one fluent query-plan API, selected by
the config:

* ``lam`` set, execution ``host|batched`` — the 5-step subsequence
  matching pipeline (``core/matching.py``), hits are
  :class:`~repro.core.matching.MatchPair`;
* ``lam=None``, execution ``host|batched`` — window-level retrieval over
  the database rows through the registry's index kinds on the PR-1
  frontier-plan substrate, hits are window ids;
* execution ``fleet`` — the elastic sharded serving layer
  (``launch/elastic.py``): round-based shared-frontier serving by default
  (``fleet_mode="rounds"``, one packed fused-ε dispatch per merged round),
  the legacy one-shot stacked device query via ``fleet_mode="oneshot"`` or
  ``.via("fleet-oneshot")``.  Hits are global window ids and
  :meth:`Retriever.elastic` exposes resize / dead-worker controls.

Every call returns a uniform :class:`ResultSet`: hits plus the
``{query, build}`` exact-evaluation buckets and dispatch counts of the
counters underneath — the same currency as the paper's pruning figures, so
facade calls are count-identical to the direct code paths (property-tested
in ``tests/test_retrieval.py``).
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import _deprecation
from repro.retrieval.config import RetrievalConfig

#: doubling cap for auto-ranged ``nearest()`` (no eps_max given)
_MAX_DOUBLINGS = 60


@dataclasses.dataclass
class ResultSet:
    """Uniform query result: hits + evaluation accounting.

    ``hits`` is a list of :class:`~repro.core.matching.MatchPair` (matcher
    mode) or window ids (window/fleet mode); for ``batch()`` plans it is a
    per-query list of such lists.  ``stats`` always carries the
    ``{"query", "build"}`` exact-eval buckets and the dispatch counts;
    batched executions add ``rounds``, fleet adds ``device_evals``.
    ``distances`` is filled by window-mode ``nearest()``.
    """

    hits: list
    stats: Dict[str, int]
    distances: Optional[list] = None

    def __iter__(self):
        return iter(self.hits)

    def __len__(self) -> int:
        return len(self.hits)

    def __bool__(self) -> bool:
        return bool(self.hits)

    @property
    def first(self):
        return self.hits[0] if self.hits else None


class QueryPlan:
    """A fluent, immutable description of one query (or query batch).

    Terminal calls — :meth:`range`, :meth:`nearest`, :meth:`longest` —
    compile the plan onto the configured engine and return a
    :class:`ResultSet`.  Modifiers return new plans:

    * :meth:`via` — override the execution policy for this call only
      (``host`` vs ``batched``; on a fleet retriever ``host`` is the
      per-shard parity loop, ``batched`` the config's fleet mode, and
      ``fleet-rounds`` / ``fleet-oneshot`` pin the shared-frontier
      round-based path or the legacy one-shot stacked device query);
    * :meth:`lb` — override the config's LB-cascade tier for this call
      (``"off" | "endpoint" | "envelope"``, legacy booleans accepted; hit
      sets are unchanged by construction — only exact-eval counts drop);
    * :meth:`dead` — mask fleet workers out of this call (fault-tolerance
      path; results degrade to the union of the survivors).
    """

    def __init__(self, retriever: "Retriever", queries: List[np.ndarray],
                 is_batch: bool, execution: Optional[str] = None,
                 lb_cascade: Optional[bool] = None,
                 dead_workers: tuple = ()):
        self._r = retriever
        self._queries = queries
        self._is_batch = is_batch
        self._execution = execution
        self._lb = lb_cascade
        self._dead = dead_workers

    def _clone(self, **kw) -> "QueryPlan":
        args = dict(execution=self._execution, lb_cascade=self._lb,
                    dead_workers=self._dead)
        args.update(kw)
        return QueryPlan(self._r, self._queries, self._is_batch, **args)

    def via(self, execution: str) -> "QueryPlan":
        allowed = ("host", "batched")
        if self._r.is_fleet:
            allowed += ("fleet-rounds", "fleet-oneshot")
        if execution not in allowed:
            raise ValueError(
                f"via() accepts {allowed} on this retriever; "
                f"got {execution!r}")
        return self._clone(execution=execution)

    def lb(self, tier=True) -> "QueryPlan":
        from repro.distances import bounds as dist_bounds
        tier = dist_bounds.normalize_tier(tier)
        if self._r.is_fleet and tier == "endpoint":
            raise ValueError(
                "the fleet path supports lb('envelope') (or 'off') only; "
                "the endpoint tier belongs to the host/batched engine")
        return self._clone(lb_cascade=tier)

    def dead(self, *workers: str) -> "QueryPlan":
        if not self._r.is_fleet:
            raise ValueError("dead() only applies to fleet execution")
        return self._clone(dead_workers=self._dead + workers)

    # -- terminals -----------------------------------------------------------

    def range(self, eps: float) -> ResultSet:
        return self._r._range(self, float(eps))

    def nearest(self, eps_max: Optional[float] = None, *,
                tol: float = 1e-2) -> ResultSet:
        return self._r._nearest(self, eps_max, tol)

    def longest(self, eps: float) -> ResultSet:
        return self._r._longest(self, float(eps))


class ElasticHandle:
    """PR-3 fleet controls, reachable only when execution is ``fleet``."""

    def __init__(self, engine: "_FleetEngine"):
        self._e = engine

    @property
    def index(self):
        """The underlying :class:`~repro.launch.elastic.ElasticIndex`."""
        return self._e.fleet

    @property
    def workers(self) -> List[str]:
        return list(self._e.fleet.workers)

    @property
    def dead(self) -> List[str]:
        return sorted(self._e.dead)

    @property
    def device_stats(self) -> Dict[str, int]:
        return dict(self._e.fleet.device_stats)

    def resize(self, workers: Sequence[str]) -> float:
        """Reshard incrementally onto a new worker set; returns the moved
        fraction.  The dead mask is cleared: survivors come out of the
        reshard with healthy shards, and masked workers dropped from the
        set no longer exist to mask."""
        frac = self._e.fleet.resize(list(workers))
        self._e.dead.clear()
        return frac

    def mark_dead(self, *workers: str) -> "ElasticHandle":
        """Mask workers out of subsequent queries (until revived/resized)."""
        self._e.dead |= set(workers)
        return self

    def revive(self, *workers: str) -> "ElasticHandle":
        self._e.dead -= set(workers)
        return self


# -- engines ------------------------------------------------------------------


class _MatcherEngine:
    """lam set: the 5-step matching pipeline (``SubsequenceMatcher``)."""

    def __init__(self, cfg: RetrievalConfig, seqs):
        from repro.core.matching import SubsequenceMatcher
        self.matcher = SubsequenceMatcher(
            cfg.dist, cfg.lam, cfg.lambda0, index=cfg.index,
            eps_prime=cfg.eps_prime, num_max=cfg.num_max,
            tight_bounds=cfg.tight_bounds, mv_refs=cfg.mv_refs,
            backend=cfg.effective_backend, lb_cascade=cfg.lb_cascade,
            kernel_exec=cfg.kernel_exec, kernel_tile=cfg.kernel_tile,
            batched=(cfg.execution == "batched"),
            bulk_build=cfg.bulk_build).build(seqs)

    @property
    def counter(self):
        return self.matcher.index.counter

    @contextlib.contextmanager
    def overrides(self, execution: Optional[str],
                  lb: Optional[bool]):
        """Per-call execution/LB toggles, restored afterwards."""
        m = self.matcher
        prev = (m.batched, m.lb_cascade, m.engine.lb_cascade)
        if execution is not None:
            m.batched = execution == "batched"
        if lb is not None:
            from repro.distances import bounds as dist_bounds
            tier = dist_bounds.normalize_tier(lb)
            m.lb_cascade = tier
            m.engine.lb_cascade = tier
        try:
            yield
        finally:
            m.batched, m.lb_cascade, m.engine.lb_cascade = prev

    def range(self, Q, eps):
        return self.matcher.query_range(Q, eps)

    def nearest(self, Q, eps_max, tol):
        return self.matcher.query_nearest(Q, eps_max, tol=tol)

    def longest(self, Q, eps):
        return self.matcher.query_longest(Q, eps)

    def has_hits(self, Q, eps, execution=None, lb=None) -> bool:
        # execution/lb are already applied by the enclosing overrides()
        return bool(self.matcher.segment_hits(Q, eps))


class _WindowEngine:
    """lam=None: window-level retrieval over the database rows."""

    def __init__(self, cfg: RetrievalConfig, data):
        from repro.core.counter import CountedDistance
        self.cfg = cfg
        self.spec = cfg.index_spec
        dist = cfg.dist
        data = self.spec.prepare_data(data)
        self.counter = CountedDistance(dist, data,
                                       backend=cfg.effective_backend,
                                       kernel_exec=cfg.kernel_exec,
                                       kernel_tile=cfg.kernel_tile)
        self.index = self.spec.factory(dist, data, counter=self.counter,
                                       **self.spec.tuning(cfg))
        if self.spec.bulk and cfg.bulk_build:
            self.index.build_batched(max_cohort=cfg.max_cohort)
        else:
            self.index.build()
        self.rounds = 0   # merged engine rounds across batched calls

    def _rows(self, queries) -> List[np.ndarray]:
        return [self.spec.prepare_query(q) for q in queries]

    def range_many(self, queries, eps, execution,
                   lb: Optional[bool] = None) -> List[List[int]]:
        from repro.core.batch_engine import BatchEngine
        cascade = self.cfg.lb_cascade if lb is None else lb
        rows = self._rows(queries)
        if execution == "host":
            # lint: allow[dispatch-in-loop] -- via("host") contract: sequential per-query loop IS the requested execution mode
            return [self.index.range_query(q, eps, lb_cascade=cascade)
                    for q in rows]
        # batched: ALL plans — every length bucket — through ONE engine run
        # (each merged round is one packed ragged-bucket dispatch)
        if not rows:
            return []
        engine = BatchEngine(self.counter, lb_cascade=cascade)
        out = engine.run([self.index.range_query_plan(eps) for _ in rows],
                         rows, eps)
        self.rounds += engine.rounds
        return out

    def nearest_one(self, q, eps_max, tol, execution,
                    lb: Optional[bool] = None):
        """Binary search on eps over range queries (cf. paper type III)."""
        row = self.spec.prepare_query(q)
        lo, hi = 0.0, float(eps_max)
        if not self.range_many([q], hi, execution, lb)[0]:
            return None
        while hi - lo > tol:
            mid = 0.5 * (lo + hi)
            if self.range_many([q], mid, execution, lb)[0]:
                hi = mid
            else:
                lo = mid
        hits = self.range_many([q], hi, execution, lb)[0]
        ds = self.counter.eval(row, hits)
        best = int(np.argmin(ds))
        return int(hits[best]), float(ds[best])

    def has_hits(self, q, eps, execution="host",
                 lb: Optional[bool] = None) -> bool:
        return bool(self.range_many([q], eps, execution, lb)[0])


class _FleetEngine:
    """execution='fleet': the PR-3 elastic sharded serving layer."""

    def __init__(self, cfg: RetrievalConfig, data):
        from repro.launch.elastic import ElasticIndex
        self.cfg = cfg
        self.fleet = ElasticIndex(
            cfg.dist, data, list(cfg.workers), eps_prime=cfg.eps_prime,
            tight_bounds=cfg.tight_bounds, backend=cfg.effective_backend,
            kernel_exec=cfg.kernel_exec, kernel_tile=cfg.kernel_tile,
            max_cohort=cfg.max_cohort, interpret=cfg.interpret,
            fleet_mode=cfg.fleet_mode, lb_cascade=cfg.lb_cascade)
        self.dead: set = set()

    def range_many(self, queries, eps, execution, extra_dead=(),
                   lb=None) -> List[List[int]]:
        dead = tuple(sorted(self.dead | set(extra_dead)))
        prev = self.fleet.lb_cascade
        if lb is not None:   # per-call tier override (envelope/off only;
            self.fleet.lb_cascade = lb   # QueryPlan.lb validates)
        try:
            if execution == "host":
                # lint: allow[dispatch-in-loop] -- via("host") contract: sequential per-query loop IS the requested execution mode
                return [self.fleet.range_query(q, eps, dead=dead,
                                               batched=False)
                        for q in queries]
            # "batched" follows the config's fleet_mode; the via()
            # modifiers pin a specific serving path for this call only
            mode = {"fleet-rounds": "rounds",
                    "fleet-oneshot": "oneshot"}.get(execution)
            return self.fleet.range_query_batch(queries, eps, dead=dead,
                                                mode=mode)
        finally:
            self.fleet.lb_cascade = prev


# -- the facade ---------------------------------------------------------------


class Retriever:
    """One object per configured retrieval stack; see the module docstring.

    Build with :meth:`Retriever.build` — the constructor is internal.
    """

    def __init__(self, config: RetrievalConfig, engine, mode: str):
        self.config = config
        self._engine = engine
        self._mode = mode   # "matcher" | "window" | "fleet"

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, config: RetrievalConfig, data) -> "Retriever":
        """Build the configured stack over ``data``.

        ``data`` is a sequence list for the matching pipeline (``lam``
        set), a ``(N, l[, d])`` window array for window-level retrieval,
        or ``(N, d)`` pooled vectors for ``index='embedding'``.
        """
        if not isinstance(config, RetrievalConfig):
            raise TypeError(
                f"expected a RetrievalConfig; got {type(config).__name__}")
        with _deprecation.facade_construction():
            if config.execution == "fleet":
                return cls(config, _FleetEngine(config, data), "fleet")
            if config.lam is not None:
                return cls(config, _MatcherEngine(config, data), "matcher")
            return cls(config, _WindowEngine(config, data), "window")

    # -- fluent entry points -------------------------------------------------

    def query(self, Q) -> QueryPlan:
        """Plan a single query (sequence, window, or embedding vector)."""
        return QueryPlan(self, [np.asarray(Q)], is_batch=False)

    def batch(self, queries) -> QueryPlan:
        """Plan a batch of queries (answered concurrently where the
        execution policy allows: frontier engine / stacked fleet query)."""
        return QueryPlan(self, [np.asarray(q) for q in queries],
                         is_batch=True)

    def elastic(self) -> ElasticHandle:
        """Fleet controls (resize / dead-worker masking); fleet-only."""
        if self._mode != "fleet":
            raise ValueError(
                "elastic() requires execution='fleet' "
                f"(this retriever runs {self.config.execution!r})")
        return ElasticHandle(self._engine)

    def serve(self, eps: float = 1.0):
        """A continuous-batching :class:`~repro.serve.engine.ServeEngine`
        over this retriever's fleet (PR 9): asynchronous requests join the
        shared frontier cadence mid-flight, one packed dispatch per merged
        round, zero-downtime snapshot-swap ``resize()``.  Configured by the
        ``serve_*`` config fields; fleet-only."""
        if self._mode != "fleet":
            raise ValueError(
                "serve() requires execution='fleet' "
                f"(this retriever runs {self.config.execution!r})")
        from repro.serve.engine import ServeConfig, ServeEngine
        cfg = self.config
        return ServeEngine(self._engine.fleet, ServeConfig(
            eps=eps, max_inflight=cfg.serve_max_inflight,
            admission=cfg.serve_admission,
            snapshot_dir=cfg.serve_snapshot_dir))

    # -- introspection -------------------------------------------------------

    @property
    def is_fleet(self) -> bool:
        return self._mode == "fleet"

    @property
    def matcher(self):
        """The underlying ``SubsequenceMatcher`` (matcher mode only)."""
        if self._mode != "matcher":
            raise ValueError("no matcher: lam is not set on this config")
        return self._engine.matcher

    @property
    def index(self):
        """The underlying index object (window mode only)."""
        if self._mode != "window":
            raise ValueError("no bare index: this retriever runs "
                             f"{self._mode} mode")
        return self._engine.index

    @property
    def meta(self):
        """Window metadata (matcher mode: step-1 partition windows)."""
        return self.matcher.meta

    def eval_stats(self) -> Dict[str, int]:
        """Cumulative ``{query, build}`` exact-eval buckets + dispatches."""
        if self._mode == "fleet":
            out = self._engine.fleet.eval_count()
            out["device_evals"] = self._engine.fleet.device_stats[
                "total_evals"]
            return out
        c = self._engine.counter
        return {"query": c.count, "build": c.build_count,
                "dispatches": c.dispatches,
                "build_dispatches": c.build_dispatches, "lb": c.lb_count}

    def reset_counter(self) -> None:
        """Zero the query-side counters (build buckets included, matching
        the legacy ``reset_counter`` semantics)."""
        if self._mode == "fleet":
            raise ValueError("fleet counters are monotone by design "
                             "(retired-shard accounting); snapshot "
                             "eval_stats() instead")
        self._engine.counter.reset()
        if self._mode == "window":
            self._engine.rounds = 0

    # -- terminal implementations -------------------------------------------

    def _snap(self) -> Dict[str, int]:
        return dict(self.eval_stats())

    def _finish(self, hits, before: Dict[str, int], distances=None,
                rounds: Optional[int] = None) -> ResultSet:
        after = self.eval_stats()
        stats = {"query": after["query"] - before["query"],
                 "build": after["build"]}
        for k in ("dispatches", "lb"):
            if k in after:
                stats[k] = after[k] - before[k]
        if "build_dispatches" in after:
            stats["build_dispatches"] = after["build_dispatches"]
        if "device_evals" in after:
            stats["device_evals"] = (after["device_evals"]
                                     - before["device_evals"])
        if rounds is not None:
            stats["rounds"] = rounds
        return ResultSet(hits=hits, stats=stats, distances=distances)

    def _execution(self, plan: QueryPlan) -> str:
        if plan._execution is not None:
            return plan._execution
        return "batched" if self._mode == "fleet" else self.config.execution

    def _range(self, plan: QueryPlan, eps: float) -> ResultSet:
        before = self._snap()
        execution = self._execution(plan)
        rounds = None
        if self._mode == "matcher":
            with self._engine.overrides(execution, plan._lb):
                per_q = [self._engine.range(Q, eps) for Q in plan._queries]
        elif self._mode == "window":
            r0 = self._engine.rounds
            per_q = self._engine.range_many(plan._queries, eps, execution,
                                            plan._lb)
            if execution == "batched":
                rounds = self._engine.rounds - r0
        else:
            per_q = self._engine.range_many(plan._queries, eps, execution,
                                            extra_dead=plan._dead,
                                            lb=plan._lb)
        hits = per_q if plan._is_batch else per_q[0]
        return self._finish(hits, before, rounds=rounds)

    def _auto_eps_max(self, Q, execution, lb=None) -> Optional[float]:
        """Double eps from the index scale until the filter fires."""
        e = max(self.config.eps_prime, 1e-6)
        for _ in range(_MAX_DOUBLINGS):
            if self._engine.has_hits(Q, e, execution, lb):
                return e
            e *= 2.0
        return None

    def _nearest(self, plan: QueryPlan, eps_max: Optional[float],
                 tol: float) -> ResultSet:
        if self._mode == "fleet":
            raise ValueError(
                "fleet execution serves range queries; nearest/longest run "
                "under host/batched execution")
        before = self._snap()
        execution = self._execution(plan)
        bests, dists = [], []
        if self._mode == "matcher":
            with self._engine.overrides(execution, plan._lb):
                for Q in plan._queries:
                    hi = eps_max if eps_max is not None \
                        else self._auto_eps_max(Q, execution, plan._lb)
                    m = None if hi is None \
                        else self._engine.nearest(Q, hi, tol)
                    bests.append(m)
                    dists.append(m.distance if m is not None else None)
        else:
            for Q in plan._queries:
                hi = eps_max if eps_max is not None \
                    else self._auto_eps_max(Q, execution, plan._lb)
                got = None if hi is None \
                    else self._engine.nearest_one(Q, hi, tol, execution,
                                                  plan._lb)
                bests.append(got[0] if got else None)
                dists.append(got[1] if got else None)
        if not plan._is_batch:
            bests, dists = bests[0], dists[0]
            bests = [] if bests is None else [bests]
            dists = [] if dists is None else [dists]
        return self._finish(bests, before, distances=dists)

    def _longest(self, plan: QueryPlan, eps: float) -> ResultSet:
        if self._mode != "matcher":
            raise ValueError(
                "longest() is a subsequence-matching query (type II); "
                "set lam on the config")
        before = self._snap()
        with self._engine.overrides(self._execution(plan), plan._lb):
            bests = [self._engine.longest(Q, eps) for Q in plan._queries]
        if not plan._is_batch:
            bests = [] if bests[0] is None else [bests[0]]
        return self._finish(bests, before)
