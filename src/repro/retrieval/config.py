"""`RetrievalConfig` — ONE declarative description of a retrieval stack.

The paper's pitch is genericity: one framework, any consistent distance,
any workload.  This dataclass is where *what* (distance, query scope) and
*how* (index kind, counter backend, execution policy) meet, validated once
at construction instead of scattered across five constructors' keyword
lists:

=============  =============================================================
field          meaning
=============  =============================================================
distance       registry name (or ``Distance`` instance) — §4 consistency /
               metricity requirements are checked here
lam, lambda0   subsequence-matching scope (§3.2).  ``lam=None`` = plain
               window-level retrieval over the database rows; ``lam`` set =
               the full 5-step matching pipeline
index          index kind from the retrieval registry
               (``refnet|covertree|mv|linear|embedding|...``)
execution      ``host`` (sequential frontier drive, classic counts),
               ``batched`` (PR-1 frontier engine, one dispatch per merged
               round), ``fleet`` (PR-3 elastic sharded serving)
backend        counter backend: ``numpy | jax | pallas``
kernel_backend device-kernel substrate override, orthogonal to
               ``execution`` (host / batched / fleet all evaluate on it);
               ``None`` follows ``backend``.  ``pallas`` routes every
               dispatch through the kernel registry's packed ragged-bucket
               dispatcher with fused ε-pruning
kernel_exec    wavefront execution mode for the pallas substrate:
               ``pallas`` (the banded VMEM-blocked kernel) or ``scan``
               (the compiled ``lax.scan`` wavefront — the CPU-CI win);
               ``None`` follows the kernel registry's process-wide policy
               (``REPRO_KERNEL_EXEC`` env var, default ``pallas``)
kernel_tile    anti-diagonal band depth of the tiled Pallas wavefront
               (static per shape); ``None`` = the registry's VMEM-budget
               heuristic (``registry.default_tile``)
lb_cascade     tiered LB policy screening verdict frontiers before the
               exact DP: ``"off" | "endpoint" | "envelope"`` (legacy
               booleans normalize to off/endpoint).  ``endpoint`` runs the
               O(B) first/last-element bounds; ``envelope`` additionally
               runs the O(B*L) elementwise envelope kernel on the
               survivors.  Fleet execution accepts ``envelope`` only
               (gathered from precomputed FlatNet envelopes)
workers        fleet worker names (or an int count); fleet execution only
fleet_mode     fleet serving mode: ``rounds`` (default — shared-frontier
               round-based serving through the packed fused-ε dispatcher,
               eval counts match the host loop) or ``oneshot`` (legacy
               single stacked device query); fleet execution only
eps_prime,     index tuning knobs (reference-net radii / parent cap /
num_max,       exact-vs-Lemma-4 bounds / MV reference count)
tight_bounds,
mv_refs
bulk_build     build hierarchies through the PR-2 cohort loader (default);
               ``False`` = sequential Alg.-1 inserts (legacy counts)
max_cohort     cohort size cap for the bulk loader / fleet shard builds
interpret      run Pallas kernels in interpret mode (off-TPU)
serve_*        continuous-batching serve engine (``Retriever.serve()``,
               PR 9): ``serve_max_inflight`` caps concurrently in-flight
               requests, ``serve_admission`` picks the admission policy
               (``tick`` = newcomers merge into the next shared round,
               ``greedy`` = one dedicated first round), and
               ``serve_snapshot_dir`` hosts the zero-downtime
               snapshot/restore checkpoints (default: a fresh temp dir)
=============  =============================================================

``to_json`` / ``from_json`` round-trip the config so serving configs are
checkable artifacts (``launch/serve.py --config path.json``).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple, Union

from repro.core.counter import BACKENDS
from repro.distances import base as dist_base
from repro.retrieval import registry

EXECUTIONS = ("host", "batched", "fleet")


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    distance: Union[str, dist_base.Distance]
    lam: Optional[int] = None
    lambda0: int = 1
    index: str = "refnet"
    execution: str = "batched"
    backend: str = "numpy"
    kernel_backend: Optional[str] = None
    kernel_exec: Optional[str] = None
    kernel_tile: Optional[int] = None
    lb_cascade: Union[bool, str] = False
    workers: Optional[Tuple[str, ...]] = None
    fleet_mode: str = "rounds"
    eps_prime: float = 1.0
    num_max: Optional[int] = None
    tight_bounds: bool = False
    mv_refs: int = 5
    bulk_build: bool = True
    max_cohort: int = 256
    interpret: bool = True
    serve_max_inflight: int = 32
    serve_admission: str = "tick"
    serve_snapshot_dir: Optional[str] = None

    # -- validation (the whole point: fail at construction, not mid-query) --

    def __post_init__(self):
        if isinstance(self.workers, int):
            object.__setattr__(
                self, "workers",
                tuple(f"w{i}" for i in range(self.workers)))
        elif self.workers is not None:
            object.__setattr__(self, "workers", tuple(self.workers))
        # normalize the tiered LB policy once (legacy booleans included),
        # so every engine below sees a canonical tier string and the JSON
        # round-trip serializes the normalized form
        from repro.distances import bounds as dist_bounds
        object.__setattr__(self, "lb_cascade",
                           dist_bounds.normalize_tier(self.lb_cascade))

        dist = dist_base.resolve(self.distance)   # raises on unknown names
        spec = registry.resolve_index(self.index)  # raises on unknown kinds
        if self.execution not in EXECUTIONS:
            raise ValueError(
                f"execution must be one of {EXECUTIONS}; "
                f"got {self.execution!r}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}; got {self.backend!r}")
        if self.kernel_backend is not None \
                and self.kernel_backend not in BACKENDS:
            raise ValueError(
                f"kernel_backend must be one of {BACKENDS} (or None to "
                f"follow 'backend'); got {self.kernel_backend!r}")
        if self.kernel_exec is not None:
            from repro.kernels.registry import EXEC_MODES
            if self.kernel_exec not in EXEC_MODES:
                raise ValueError(
                    f"kernel_exec must be one of {EXEC_MODES} (or None to "
                    f"follow the registry policy); got {self.kernel_exec!r}")
        if self.kernel_tile is not None and self.kernel_tile < 1:
            raise ValueError(
                f"kernel_tile must be >= 1 (or None for the VMEM-budget "
                f"heuristic); got {self.kernel_tile}")

        if self.lam is not None:
            if self.lam < 2:
                raise ValueError(f"lam must be >= 2; got {self.lam}")
            if not 0 <= self.lambda0 < self.lam // 2:
                raise ValueError(
                    f"lambda0 must satisfy 0 <= lambda0 < lam/2 "
                    f"(= {self.lam // 2}); got {self.lambda0}")
            dist_base.require_consistent(dist)   # segmentation filter, Def. 1
            if self.index == "embedding":
                raise ValueError(
                    "index 'embedding' serves fixed-length pooled vectors; "
                    "it cannot back the subsequence-matching pipeline "
                    "(set lam=None)")
        if spec.requires_metric:
            dist_base.require_metric(dist)       # indexed path, §5

        if self.execution == "fleet":
            if not self.workers:
                raise ValueError(
                    "fleet execution needs workers (a name tuple or count)")
            if self.lam is not None:
                raise ValueError(
                    "fleet execution serves window-level range queries; "
                    "the matching pipeline (lam) runs host/batched")
            if self.index != "refnet":
                raise ValueError(
                    "fleet execution shards per-worker reference nets; "
                    f"index must be 'refnet', got {self.index!r}")
            if self.lb_cascade == "endpoint":
                raise ValueError(
                    "fleet execution supports lb_cascade='envelope' only "
                    "(gathered from precomputed FlatNet envelopes); the "
                    "endpoint tier belongs to the host/batched frontier "
                    "engine")
            from repro.launch.elastic import FLEET_MODES
            if self.fleet_mode not in FLEET_MODES:
                raise ValueError(
                    f"fleet_mode must be one of {FLEET_MODES}; "
                    f"got {self.fleet_mode!r}")
        else:
            if self.workers is not None:
                raise ValueError(
                    f"workers only apply to fleet execution "
                    f"(execution={self.execution!r})")
            if self.fleet_mode != "rounds":
                raise ValueError(
                    f"fleet_mode only applies to fleet execution "
                    f"(execution={self.execution!r})")

        # serve knobs (Retriever.serve(); validated here regardless of
        # execution so a bad serving config fails at construction, not when
        # the engine is finally asked for)
        from repro.serve.engine import ADMISSION_POLICIES
        if self.serve_max_inflight < 1:
            raise ValueError(
                f"serve_max_inflight must be >= 1; "
                f"got {self.serve_max_inflight}")
        if self.serve_admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"serve_admission must be one of {ADMISSION_POLICIES}; "
                f"got {self.serve_admission!r}")

    # -- resolution helpers --------------------------------------------------

    @property
    def dist(self) -> dist_base.Distance:
        return dist_base.resolve(self.distance)

    @property
    def effective_backend(self) -> str:
        """The device-kernel substrate every engine evaluates on.

        ``kernel_backend`` is orthogonal to ``execution``: host, batched,
        and fleet engines all funnel their evaluations through the same
        counter/kernel-registry substrate, and this selects it.  ``None``
        follows the legacy ``backend`` field."""
        return self.kernel_backend or self.backend

    @property
    def index_spec(self) -> registry.IndexSpec:
        return registry.resolve_index(self.index)

    def replace(self, **changes) -> "RetrievalConfig":
        return dataclasses.replace(self, **changes)

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        dist = self.dist
        if isinstance(self.distance, dist_base.Distance):
            # an instance serializes by name, so the name must round-trip
            # back to the SAME distance when the JSON is loaded
            try:
                registered = dist_base.get(dist.name) is dist
            except KeyError:
                registered = False
            if not registered:
                raise ValueError(
                    f"distance {dist.name!r} is not in the registry; "
                    "register it (repro.retrieval.register_distance) "
                    "before serializing this config")
        d["distance"] = dist.name
        if self.workers is not None:
            d["workers"] = list(self.workers)
        return d

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "RetrievalConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(
                f"unknown RetrievalConfig fields: {sorted(extra)}")
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "RetrievalConfig":
        return cls.from_dict(json.loads(s))
