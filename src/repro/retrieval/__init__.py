"""`repro.retrieval` — the canonical entry point to the framework.

One declarative :class:`RetrievalConfig`, one :class:`Retriever` facade
over every index kind and execution engine (host / batched frontier
engine / elastic fleet), with pluggable registries for third-party
distances and indexes.  See ``facade.py`` for the query-plan API.
"""

from repro.retrieval.config import EXECUTIONS, RetrievalConfig  # noqa: F401
from repro.retrieval.facade import (  # noqa: F401
    ElasticHandle, QueryPlan, ResultSet, Retriever)
from repro.retrieval.registry import (  # noqa: F401
    IndexSpec, distance_names, index_names, register_distance,
    register_index, resolve_distance, resolve_index, unregister_distance,
    unregister_index)
