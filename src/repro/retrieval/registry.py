"""Pluggable registries behind the ``repro.retrieval`` facade.

Two axes of genericity (Novak et al., arXiv:1206.2510: separate *what* —
the distance — from *how* — the index):

* **Distances** live in the global registry of ``repro.distances``;
  :func:`register_distance` re-exports registration in decorator-friendly
  form so third parties can add a distance and immediately name it in a
  :class:`~repro.retrieval.config.RetrievalConfig`.
* **Index kinds** are described by an :class:`IndexSpec` — a factory plus
  the declarative facts the facade needs (does it require metricity, does
  it support the cohort bulk loader, which config fields map onto its
  constructor, how are database rows / query rows shaped).  The built-in
  kinds (``refnet``, ``covertree``, ``mv``, ``linear``, ``embedding``)
  register themselves here; ``@register_index("mykind")`` adds new ones.

Factories import the core classes lazily so this module stays import-cycle
free (core modules may import the registry to resolve index kinds).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from repro.distances import base as dist_base


# -- distance registry --------------------------------------------------------

def register_distance(obj):
    """Register a distance with the global registry and return it.

    Usable three ways: ``register_distance(distance_instance)``, as a
    decorator over a zero-argument factory function returning a
    :class:`~repro.distances.base.Distance`, or via
    ``repro.distances.base.register`` directly.
    """
    if isinstance(obj, dist_base.Distance):
        return dist_base.register(obj)
    made = obj()
    if not isinstance(made, dist_base.Distance):
        raise TypeError(
            "@register_distance expects a Distance or a zero-arg factory "
            f"returning one; got {made!r}")
    return dist_base.register(made)


def unregister_distance(name: str) -> None:
    """Remove a distance from the global registry (test hygiene)."""
    dist_base._REGISTRY.pop(name, None)


def distance_names():
    return dist_base.names()


resolve_distance = dist_base.resolve


# -- index registry -----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """Everything the facade needs to know about an index kind."""

    name: str
    #: ``factory(dist, data, *, counter=None, **tuning) -> index`` where the
    #: index exposes ``build()`` (and ``build_batched()`` when ``bulk``),
    #: ``range_query(q, eps, q_len, *, lb_cascade)`` and
    #: ``range_query_plan(eps)`` on the frontier-plan substrate.
    factory: Callable
    #: triangle inequality required (paper §5) — checked at config time
    requires_metric: bool = True
    #: supports the PR-2 cohort bulk loader (``build_batched``)
    bulk: bool = False
    #: config-like object -> constructor kwargs
    tuning: Callable = lambda cfg: {}
    #: reshape the caller's database before the counter sees it
    prepare_data: Callable = np.asarray
    #: reshape one query before it meets ``range_query`` / the engine
    prepare_query: Callable = np.asarray


_INDEXES: Dict[str, IndexSpec] = {}


def register_index(name: str, *, requires_metric: bool = True,
                   bulk: bool = False, tuning: Optional[Callable] = None,
                   prepare_data: Optional[Callable] = None,
                   prepare_query: Optional[Callable] = None):
    """Decorator registering an index factory under ``name``.

    The decorated callable becomes :attr:`IndexSpec.factory`; the keyword
    facts describe it to the facade (see :class:`IndexSpec`).
    """
    if name in _INDEXES:
        raise ValueError(f"index kind {name!r} already registered")

    def deco(factory: Callable) -> Callable:
        _INDEXES[name] = IndexSpec(
            name=name, factory=factory, requires_metric=requires_metric,
            bulk=bulk, tuning=tuning or (lambda cfg: {}),
            prepare_data=prepare_data or np.asarray,
            prepare_query=prepare_query or np.asarray)
        return factory

    return deco


def unregister_index(name: str) -> None:
    """Remove an index kind (test hygiene)."""
    _INDEXES.pop(name, None)


def resolve_index(name: str) -> IndexSpec:
    if name not in _INDEXES:
        raise KeyError(
            f"unknown index kind {name!r}; have {sorted(_INDEXES)}")
    return _INDEXES[name]


def index_names():
    return sorted(_INDEXES)


# -- built-in index kinds -----------------------------------------------------

def _refnet_tuning(cfg) -> dict:
    return dict(eps_prime=cfg.eps_prime, num_max=cfg.num_max,
                tight_bounds=cfg.tight_bounds)


@register_index("refnet", requires_metric=True, bulk=True,
                tuning=_refnet_tuning)
def _make_refnet(dist, data, *, counter=None, **kw):
    from repro.core.refnet import ReferenceNet
    return ReferenceNet(dist, data, counter=counter, **kw)


@register_index("covertree", requires_metric=True, bulk=True,
                tuning=lambda cfg: dict(eps_prime=cfg.eps_prime,
                                        tight_bounds=cfg.tight_bounds))
def _make_covertree(dist, data, *, counter=None, **kw):
    from repro.core.covertree import CoverTree
    return CoverTree(dist, data, counter=counter, **kw)


@register_index("mv", requires_metric=True,
                tuning=lambda cfg: dict(n_refs=cfg.mv_refs))
def _make_mv(dist, data, *, counter=None, **kw):
    from repro.core.refindex import MVReferenceIndex
    return MVReferenceIndex(dist, data, counter=counter, **kw)


@register_index("linear", requires_metric=False)
def _make_linear(dist, data, *, counter=None, **kw):
    from repro.core.matching import LinearScanIndex
    return LinearScanIndex(dist, data, counter=counter, **kw)


def _embed_data(vectors) -> np.ndarray:
    """(N, d) pooled vectors -> (N, 1, d) length-1 sequences so the registry
    distances apply (see ``core/embedding_retrieval.py``)."""
    vectors = np.asarray(vectors)
    if vectors.ndim != 2:
        raise ValueError(
            f"embedding index expects (N, d) vectors; got {vectors.shape}")
    return vectors[:, None, :]


def _embed_query(vec) -> np.ndarray:
    vec = np.asarray(vec)
    if vec.ndim == 1:
        return vec[None, :]
    return vec


@register_index("embedding", requires_metric=True, bulk=True,
                tuning=_refnet_tuning,
                prepare_data=_embed_data, prepare_query=_embed_query)
def _make_embedding(dist, data, *, counter=None, **kw):
    from repro.core.refnet import ReferenceNet
    return ReferenceNet(dist, data, counter=counter, **kw)
