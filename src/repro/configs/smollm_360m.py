"""smollm-360m [dense]: llama-arch small (15 heads — TP pads to 16)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, vocab=49152,
    n_heads=15, n_kv_heads=5, head_dim=64, d_ff=2560,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=60, vocab=256, n_heads=3, n_kv_heads=1,
        head_dim=20, d_ff=128, remat="none")
