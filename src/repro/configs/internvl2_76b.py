"""internvl2-76b [vlm]: InternLM2-76B backbone; InternViT frontend is a
stub — input_specs supplies precomputed patch embeddings
(arXiv:2404.16821)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="dense",
    n_layers=80, d_model=8192, vocab=128256,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=28672,
    frontend="vision", frontend_prefix=256,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, frontend_prefix=8, remat="none")
