"""qwen2-72b [dense]: GQA kv=8, QKV bias (arXiv:2407.10671)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, vocab=152064,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=29568,
    qkv_bias=True,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, remat="none")
