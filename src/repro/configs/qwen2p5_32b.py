"""qwen2.5-32b [dense]: GQA kv=8, QKV bias."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, vocab=152064,
    n_heads=40, n_kv_heads=8, head_dim=128, d_ff=27648,
    qkv_bias=True,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256, n_heads=5, n_kv_heads=1,
        head_dim=16, d_ff=128, remat="none")
