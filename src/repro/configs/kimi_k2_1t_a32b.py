"""kimi-k2-1t-a32b [moe]: trillion-param MLA MoE, 384 experts top-8."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, vocab=163840,
    n_heads=64, n_kv_heads=8, d_ff=18432,
    n_experts=384, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=1,
    q_lora=1536, kv_lora=512, rope_head_dim=64, nope_head_dim=128,
    v_head_dim=128,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256, n_heads=4, d_ff=128,
        n_experts=8, top_k=2, moe_d_ff=32, first_dense_layers=1,
        q_lora=32, kv_lora=32, rope_head_dim=8, nope_head_dim=16,
        v_head_dim=16, remat="none")
