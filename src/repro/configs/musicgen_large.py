"""musicgen-large [audio]: decoder-only over EnCodec tokens; the EnCodec
frontend is a stub — input_specs supplies precomputed frame embeddings
(arXiv:2306.05284)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="dense",
    n_layers=48, d_model=2048, vocab=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192,
    frontend="audio", frontend_prefix=256,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=128, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, frontend_prefix=8, remat="none")
