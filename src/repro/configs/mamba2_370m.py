"""mamba2-370m [ssm]: SSD, attention-free (arXiv:2405.21060)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    sub_quadratic=True,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, remat="none")
