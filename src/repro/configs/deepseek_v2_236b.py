"""deepseek-v2-236b [moe]: MLA kv_lora=512, 2 shared + 160 routed top-6
(arXiv:2405.04434)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, vocab=102400,
    n_heads=128, n_kv_heads=128, d_ff=12288,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    first_dense_layers=1,
    q_lora=1536, kv_lora=512, rope_head_dim=64, nope_head_dim=128,
    v_head_dim=128,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256, n_heads=4, d_ff=128,
        n_experts=8, n_shared_experts=2, top_k=2, moe_d_ff=32,
        first_dense_layers=1, q_lora=32, kv_lora=32, rope_head_dim=8,
        nope_head_dim=16, v_head_dim=16, remat="none")
