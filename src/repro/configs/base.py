"""Model configuration dataclass shared by every assigned architecture."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    # dense mlp
    d_ff: int = 0
    # MoE (+ MLA) — deepseek/kimi family
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    q_lora: int = 0                  # 0 = plain q projection
    kv_lora: int = 0                 # >0 = MLA compressed kv
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid: one shared attention block applied every k ssm blocks
    attn_every: int = 0
    # modality frontend: backbone consumes precomputed embeddings
    frontend: str = "none"           # none | audio | vision
    frontend_prefix: int = 0         # prefix embedding positions (vlm)
    # serving / training limits
    max_seq: int = 532_480
    # numerics
    param_dtype: str = "bfloat16"
    # attention chunking for long prefill (online softmax block)
    attn_chunk: int = 512
    # remat policy for training: none | block
    remat: str = "block"
    # attention flavour is derived: mla if kv_lora else gqa
    sub_quadratic: bool = False      # SSM/hybrid: supports 500k decode

    @property
    def attn_type(self) -> str:
        if self.family == "ssm":
            return "none"
        return "mla" if self.kv_lora else "gqa"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def vocab_padded(self, mult: int = 128) -> int:
        return ((self.vocab + mult - 1) // mult) * mult

    def heads_padded(self, shards: int) -> int:
        """Q-heads padded up to a multiple of the TP axis (zero extra heads)."""
        if self.n_heads == 0:
            return 0
        return ((self.n_heads + shards - 1) // shards) * shards

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        total = 2 * V * d  # embed + unembed
        if self.family in ("dense",):
            hd = self.head_dim
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
            mlp = 3 * d * self.d_ff
            total += L * (attn + mlp + 2 * d)
        elif self.family == "moe":
            attn = self._mla_params()
            dense_mlp = 3 * d * self.d_ff
            moe_mlp = 3 * d * self.moe_d_ff * (
                self.n_experts + self.n_shared_experts) + d * self.n_experts
            nd = self.first_dense_layers
            total += nd * (attn + dense_mlp + 2 * d)
            total += (L - nd) * (attn + moe_mlp + 2 * d)
        elif self.family == "ssm":
            total += L * (self._ssm_params() + d)
        elif self.family == "hybrid":
            total += L * (self._ssm_params() + d)
            hd = self.head_dim
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d + 3 * d * self.d_ff + 2 * d
            total += attn  # one shared block
        return total

    def _mla_params(self) -> int:
        d, H = self.d_model, self.n_heads
        qh = self.nope_head_dim + self.rope_head_dim
        if self.q_lora:
            q = d * self.q_lora + self.q_lora * H * qh
        else:
            q = d * H * qh
        kv = d * (self.kv_lora + self.rope_head_dim) + self.kv_lora * H * (
            self.nope_head_dim + self.v_head_dim)
        o = H * self.v_head_dim * d
        return q + kv + o

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        G, N, H = self.ssm_groups, self.ssm_state, self.ssm_heads
        in_proj = d * (2 * di + 2 * G * N + H)
        conv = (di + 2 * G * N) * self.ssm_conv
        out = di * d
        return in_proj + conv + out + 2 * H + di

    def active_param_count(self) -> int:
        """Activated params per token (= total for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        attn = self._mla_params()
        dense_mlp = 3 * d * self.d_ff
        act_mlp = 3 * d * self.moe_d_ff * (self.top_k + self.n_shared_experts) \
            + d * self.n_experts
        nd = self.first_dense_layers
        total = 2 * self.vocab * d
        total += nd * (attn + dense_mlp + 2 * d)
        total += (L - nd) * (attn + act_mlp + 2 * d)
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
