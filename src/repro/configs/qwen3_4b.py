"""qwen3-4b [dense]: GQA kv=8, qk_norm."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, vocab=151936,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728,
    qk_norm=True,
)

def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab=256, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, remat="none")
