"""One kernel registry for every device evaluation path.

Before this module existed the repo had four parallel device paths that had
drifted apart (``kernels/ops.py`` re-resolving the backend and re-laying-out
operands per call, ``core/counter.py``'s pallas branch restricted to a
single length bucket, ``core/distributed.py``'s private ``_batch_dist``,
and the jnp oracle).  Novak et al. (arXiv:1206.2510) argue for exactly one
pluggable evaluation substrate under many matching strategies; this
registry is that substrate's single entry point:

* one :class:`KernelSpec` per distance, keyed exactly like the PR-4
  distance registry (``dtw`` / ``erp`` / ``frechet`` / ``levenshtein`` —
  the wavefront modes — plus elementwise ``euclidean`` / ``hamming``, and
  one ``lb:<name>`` envelope spec per alignment distance with an envelope
  bound: the LB-cascade tier-1 kernel, pure O(B*L) elementwise work that
  shares this cache and the zero-retrace gate);
* one ``interpret`` policy: resolved against the default JAX backend once
  per process (:func:`default_interpret`), not per call — overridable via
  the ``REPRO_INTERPRET`` env var or the :func:`set_default_interpret`
  test/bench hook (the real-hardware benchmark lane pins ``False``);
* one execution-mode policy for the wavefront specs
  (:func:`default_exec`): ``"pallas"`` (the banded VMEM-blocked kernel —
  interpret-mode off-TPU, real hardware on TPU) or ``"scan"`` (the
  compiled ``lax.scan`` wavefront, the measured win on CPU CI) —
  overridable via ``REPRO_KERNEL_EXEC``, :func:`set_default_exec`,
  ``RetrievalConfig.kernel_exec``, or per call;
* one band-tile policy for the Pallas schedule: :func:`default_tile`
  picks the deepest band that fits the per-band VMEM budget (static per
  shape — part of the jit cache key), overridable via
  ``RetrievalConfig.kernel_tile`` or per call;
* one jit cache: every ``(kernel, Lx, Ly, d, batch, block, interpret,
  exec, tile)`` shape class compiles exactly once (:data:`STATS` counts
  traces — the retrace regression tests gate this);
* fused ε-pruning (Twin Subsequence Search, arXiv:2104.06874): pass
  ``eps`` and the kernel returns the hit mask and early-prune certificate
  alongside ``BIG``-masked distances, so range queries never materialize
  distances for pruned candidates.

Two calling conventions per spec:

* :meth:`KernelSpec.device_call` — *traceable*: safe inside an enclosing
  ``jax.jit`` (``core/distributed._device_query_jit`` composes it);
* :meth:`KernelSpec.batch` — host entry: numpy in/out, batch padded to a
  power of two, routed through the shared jit cache.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.wavefront import (BIG, wavefront_pallas, wavefront_scan)

#: wavefront mode <-> distance-registry name
MODE_OF_NAME = {"dtw": "dtw", "erp": "erp", "frechet": "dfd",
                "levenshtein": "lev"}
NAME_OF_MODE = {v: k for k, v in MODE_OF_NAME.items()}

#: trace/call accounting — ``traces`` increments once per kernel compile
#: (the retrace regression tests pin it), ``calls`` once per host dispatch.
STATS = {"traces": 0, "calls": 0}

_JIT_CACHE: Dict[tuple, object] = {}
_DEFAULT_INTERPRET: Optional[bool] = None

#: wavefront execution modes: the banded Pallas kernel vs the compiled
#: ``lax.scan`` wavefront (same layout, same per-diagonal math)
EXEC_MODES = ("pallas", "scan")
_DEFAULT_EXEC: Optional[str] = None

#: per-band VMEM budget (bytes) for the tiled wavefront — a conservative
#: slice of the ~16 MiB/core TPU VMEM, leaving room for double buffering
VMEM_TILE_BUDGET = 1 << 22


class KernelOut(NamedTuple):
    """One device evaluation: masked distances + fused-ε masks.

    ``dist`` holds the exact distance for rows whose verdict is a hit (or
    every row when ``eps`` was +inf/None), ``BIG`` otherwise.  ``pruned``
    marks rows certified ``> eps`` before their final diagonal (a subset
    of ``~hit``)."""
    dist: object
    hit: object
    pruned: object


def default_interpret() -> bool:
    """Interpret-mode policy, resolved ONCE per process.

    Resolution order: a value pinned by :func:`set_default_interpret`, the
    ``REPRO_INTERPRET`` env var (``1/true/yes/on`` vs anything else), then
    the JAX backend (interpret everywhere except TPU).  The env override
    lets tests and the ``--hardware`` benchmark lane pin the policy
    without import-order games."""
    global _DEFAULT_INTERPRET
    if _DEFAULT_INTERPRET is None:
        env = os.environ.get("REPRO_INTERPRET")
        if env is not None:
            _DEFAULT_INTERPRET = \
                env.strip().lower() in ("1", "true", "yes", "on")
        else:
            _DEFAULT_INTERPRET = jax.default_backend() != "tpu"
    return _DEFAULT_INTERPRET


def set_default_interpret(value: Optional[bool]) -> Optional[bool]:
    """Pin the process-wide interpret policy (test/bench hook).

    ``None`` clears the pin so the next :func:`default_interpret` call
    re-resolves from ``REPRO_INTERPRET`` / the JAX backend.  Returns the
    previously pinned value (None if it was unresolved) so callers can
    restore it."""
    global _DEFAULT_INTERPRET
    prev = _DEFAULT_INTERPRET
    _DEFAULT_INTERPRET = None if value is None else bool(value)
    return prev


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def default_exec() -> str:
    """Wavefront execution-mode policy, resolved ONCE per process.

    ``REPRO_KERNEL_EXEC`` (``pallas`` | ``scan``) overrides the default
    (``pallas``); :func:`set_default_exec` pins it programmatically."""
    global _DEFAULT_EXEC
    if _DEFAULT_EXEC is None:
        env = os.environ.get("REPRO_KERNEL_EXEC", "").strip().lower()
        if env and env not in EXEC_MODES:
            raise ValueError(
                f"REPRO_KERNEL_EXEC must be one of {EXEC_MODES}; "
                f"got {env!r}")
        _DEFAULT_EXEC = env or "pallas"
    return _DEFAULT_EXEC


def set_default_exec(value: Optional[str]) -> Optional[str]:
    """Pin the process-wide wavefront execution mode (test/bench hook).

    ``None`` clears the pin (next resolution re-reads the env var).
    Returns the previously pinned value for restore."""
    global _DEFAULT_EXEC
    if value is not None and value not in EXEC_MODES:
        raise ValueError(
            f"exec mode must be one of {EXEC_MODES}; got {value!r}")
    prev = _DEFAULT_EXEC
    _DEFAULT_EXEC = value
    return prev


def resolve_exec(exec_mode: Optional[str]) -> str:
    if exec_mode is None:
        return default_exec()
    if exec_mode not in EXEC_MODES:
        raise ValueError(
            f"exec mode must be one of {EXEC_MODES}; got {exec_mode!r}")
    return exec_mode


def default_tile(Lx: int, Ly: int, d: int, block_b: int = 8,
                 budget: int = VMEM_TILE_BUDGET) -> int:
    """Deepest anti-diagonal band whose working set fits the VMEM budget.

    The banded kernel's per-band, per-batch-block f32 residency is the x
    tile (``(Lx+1)*d``), the band's reversed-y tile (``(Lx+tile)*(d+1)``
    including the ERP gap row), the borders, and the carry scratch (two
    diagonals + answer/liveness columns); only the y tile scales with the
    band depth, so the deepest admissible tile is linear in the budget.
    Clamped to ``[8, Lx+Ly]`` — on short segments (every CI bench shape)
    the whole DP fits one band, which is exactly the untiled schedule.
    """
    W = Lx + 1
    K = Lx + Ly
    fixed = W * d + Lx * (d + 1) + W + (Ly + 1) + 2 * W + 8
    per_t = d + 1
    T = (budget // (4 * block_b) - fixed) // per_t
    return max(8, min(int(T), K))


def clear_cache() -> None:
    """Drop compiled kernels + stats (test hygiene)."""
    _JIT_CACHE.clear()
    STATS["traces"] = 0
    STATS["calls"] = 0


def _pad_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pad_rows(a: np.ndarray, P: int) -> np.ndarray:
    if len(a) == P:
        return a
    pad = [(0, P - len(a))] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Device evaluation of one registered distance."""

    name: str                 # distance-registry key
    kind: str                 # "wavefront" | "elementwise" | "envelope"
    mode: Optional[str] = None  # wavefront DP mode (dtw/erp/dfd/lev)

    # -- traceable path ------------------------------------------------------

    def device_call(self, xs, ys, lx=None, ly=None, eps=None, *,
                    block_b: int = 8, interpret: Optional[bool] = None,
                    exec: Optional[str] = None,
                    tile: Optional[int] = None) -> KernelOut:
        """Traceable batched evaluation -> :class:`KernelOut` of jnp arrays.

        ``xs``/``ys`` are row-paired ``(B, Lx[, d])`` / ``(B, Ly[, d])``
        batches (integer tokens for the string distances); ``lx``/``ly``
        per-row actual lengths (default: the padded widths); ``eps`` a
        scalar or per-row threshold enabling the fused ε outputs.
        ``exec`` picks the wavefront execution mode (``pallas`` | ``scan``;
        None follows :func:`default_exec`) and ``tile`` the Pallas band
        depth (None: the :func:`default_tile` VMEM heuristic) — both only
        apply to the wavefront specs.
        """
        interpret = resolve_interpret(interpret)
        xs = jnp.asarray(xs)
        ys = jnp.asarray(ys)
        B = xs.shape[0]
        lx = jnp.full((B,), xs.shape[1], jnp.int32) if lx is None \
            else jnp.asarray(lx, jnp.int32)
        ly = jnp.full((B,), ys.shape[1], jnp.int32) if ly is None \
            else jnp.asarray(ly, jnp.int32)
        eps_v = jnp.full((B,), jnp.inf, jnp.float32) if eps is None \
            else jnp.broadcast_to(jnp.asarray(eps, jnp.float32), (B,))
        if self.kind == "elementwise":
            return self._elementwise(xs, ys, lx, eps_v)
        if self.kind == "envelope":
            return self._envelope(xs, ys, lx, ly, eps_v)
        return self._wavefront(xs, ys, lx, ly, eps_v, block_b=block_b,
                               interpret=interpret,
                               exec_mode=resolve_exec(exec), tile=tile)

    def _elementwise(self, xs, ys, lx, eps_v) -> KernelOut:
        L = xs.shape[1]
        mask = jnp.arange(L)[None, :] < lx[:, None]
        if self.name == "hamming":
            d = jnp.sum((xs != ys) & mask, axis=1).astype(jnp.float32)
        else:  # euclidean
            diff = xs.astype(jnp.float32) - ys.astype(jnp.float32)
            d2 = diff * diff
            if d2.ndim == 3:
                d2 = jnp.sum(d2, axis=-1)
            d = jnp.sqrt(jnp.maximum(jnp.sum(d2 * mask, axis=1), 0.0))
        hit = d <= eps_v
        return KernelOut(jnp.where(hit, d, BIG), hit,
                         jnp.zeros_like(hit))

    def _envelope(self, xs, ys, lx, ly, eps_v) -> KernelOut:
        """LB-cascade tier-1 envelope bound (O(B*L) elementwise, VPU-shaped).

        The device mirror of ``distances/bounds.py``'s two-sided envelope
        bounds (soundness proofs live there): per-row axis-aligned boxes
        over the valid positions, per-position box distances, and the
        mode-specific combine — sum (dtw), max (dfd), or the ERP element
        consumption + prefix gap-mass refinement.  ``dist`` carries the
        bound itself (never BIG-masked — pruned rows return their bound so
        callers keep the ``<= eps`` verdict); ``pruned`` certifies
        ``lb > eps``, i.e. the exact wavefront DP can be skipped."""
        xs = xs.astype(jnp.float32)
        ys = ys.astype(jnp.float32)
        if xs.ndim == 2:
            xs, ys = xs[..., None], ys[..., None]
        B, Lx, _ = xs.shape
        Ly = ys.shape[1]
        mx = jnp.arange(Lx)[None, :] < lx[:, None]
        my = jnp.arange(Ly)[None, :] < ly[:, None]
        big = jnp.float32(3.4e38)
        lo_y = jnp.where(my[..., None], ys, big).min(axis=1)
        hi_y = jnp.where(my[..., None], ys, -big).max(axis=1)
        lo_x = jnp.where(mx[..., None], xs, big).min(axis=1)
        hi_x = jnp.where(mx[..., None], xs, -big).max(axis=1)

        def box_gap(a, lo, hi):
            g = jnp.maximum(lo[:, None, :] - a, 0.0) \
                + jnp.maximum(a - hi[:, None, :], 0.0)
            return jnp.sqrt(jnp.maximum(jnp.sum(g * g, axis=-1), 0.0))

        bdx = box_gap(xs, lo_y, hi_y)          # (B, Lx)
        bdy = box_gap(ys, lo_x, hi_x)          # (B, Ly)
        if self.mode == "dfd":
            lb = jnp.maximum(jnp.max(jnp.where(mx, bdx, 0.0), axis=1),
                             jnp.max(jnp.where(my, bdy, 0.0), axis=1))
        elif self.mode == "dtw":
            lb = jnp.maximum(jnp.sum(bdx * mx, axis=1),
                             jnp.sum(bdy * my, axis=1))
        else:  # erp
            gx = jnp.where(mx, jnp.sqrt(jnp.maximum(
                jnp.sum(xs * xs, -1), 0.0)), 0.0)
            gy = jnp.where(my, jnp.sqrt(jnp.maximum(
                jnp.sum(ys * ys, -1), 0.0)), 0.0)
            cons = jnp.maximum(
                jnp.sum(jnp.minimum(gx, bdx) * mx, axis=1),
                jnp.sum(jnp.minimum(gy, bdy) * my, axis=1))
            z = jnp.zeros((B, 1), jnp.float32)
            Gx = jnp.concatenate([z, jnp.cumsum(gx, axis=1)], axis=1)
            Gy = jnp.concatenate([z, jnp.cumsum(gy, axis=1)], axis=1)
            r = jnp.arange(B)
            Tx = Gx[r, lx]
            Ty = Gy[r, ly]
            a = Gx[r, lx // 2]
            b = Tx - a
            f = jnp.abs(a[:, None] - Gy) \
                + jnp.abs(b[:, None] - (Ty[:, None] - Gy))
            valid_m = jnp.arange(Ly + 1)[None, :] <= ly[:, None]
            lb = jnp.maximum(cons, jnp.min(
                jnp.where(valid_m, f, jnp.inf), axis=1))
        hit = lb <= eps_v
        return KernelOut(lb, hit, ~hit)

    def _wavefront(self, xs, ys, lx, ly, eps_v, *, block_b, interpret,
                   exec_mode: str = "pallas",
                   tile: Optional[int] = None) -> KernelOut:
        mode = self.mode
        xs = xs.astype(jnp.float32)  # lev tokens ride as exact small floats
        ys = ys.astype(jnp.float32)
        if xs.ndim == 2:
            xs, ys = xs[..., None], ys[..., None]
        B, Lx, d = xs.shape
        Ly = ys.shape[1]

        # layout: x laid out so position i holds x[i-1]; reversed y padded so
        # diagonal k reads window start Lx+1+Ly-k (ragged rows keep their
        # zero padding at the *front* after the flip — the DP cells that
        # read it never feed the answer at (len_x, len_y))
        x_pad = jnp.pad(xs, ((0, 0), (1, 0), (0, 0)))
        Ypad = 2 * Lx + Ly + 1
        y_rev = jnp.flip(ys, axis=1)
        y_rev_pad = jnp.pad(y_rev, ((0, 0), (Lx + 1, Ypad - (Lx + 1) - Ly),
                                    (0, 0)))

        if mode == "erp":
            gx = jnp.minimum(jnp.sqrt(jnp.maximum(
                jnp.sum(xs * xs, -1), 0.0)), BIG)          # (B, Lx)
            gy = jnp.minimum(jnp.sqrt(jnp.maximum(
                jnp.sum(ys * ys, -1), 0.0)), BIG)          # (B, Ly)
            # zero the padding tail so border cumsums end at (len_x, len_y)
            gx = jnp.where(jnp.arange(Lx)[None, :] < lx[:, None], gx, 0.0)
            gy = jnp.where(jnp.arange(Ly)[None, :] < ly[:, None], gy, 0.0)
            gap_x = jnp.pad(gx, ((0, 0), (1, 0)))
            gy_rev = jnp.flip(gy, axis=1)
            gap_y_rev = jnp.pad(gy_rev,
                                ((0, 0), (Lx + 1, Ypad - (Lx + 1) - Ly)))
            zero = jnp.zeros((B, 1), jnp.float32)
            # clamp: a cumsum above the BIG sentinel would corrupt the DP's
            # quasi-infinity ordering (and overflow to inf three adds later)
            border_col = jnp.minimum(
                jnp.concatenate([zero, jnp.cumsum(gx, 1)], axis=1), BIG)
            border_row = jnp.minimum(
                jnp.concatenate([zero, jnp.cumsum(gy, 1)], axis=1), BIG)
        else:
            gap_x = jnp.zeros((B, Lx + 1), jnp.float32)
            gap_y_rev = jnp.zeros((B, Ypad), jnp.float32)
            if mode == "lev":
                border_col = jnp.broadcast_to(
                    jnp.arange(Lx + 1, dtype=jnp.float32)[None], (B, Lx + 1))
                border_row = jnp.broadcast_to(
                    jnp.arange(Ly + 1, dtype=jnp.float32)[None], (B, Ly + 1))
            else:
                big = jnp.float32(BIG)
                border_col = jnp.full((B, Lx + 1), big).at[:, 0].set(0.0)
                border_row = jnp.full((B, Ly + 1), big).at[:, 0].set(0.0)

        lens = jnp.stack([lx, ly], axis=1).astype(jnp.int32)  # (B, 2)
        eps_col = eps_v[:, None]
        args = [x_pad, y_rev_pad, gap_x, gap_y_rev, border_col, border_row,
                lens, eps_col]
        if exec_mode == "scan":
            # compiled lax.scan twin: same layout, same per-diagonal math,
            # no batch blocking or banding (XLA owns the schedule)
            dist, hit, pruned = wavefront_scan(
                *args, mode=mode, Lx=Lx, Ly=Ly, d=d)
            return KernelOut(dist, hit, pruned)
        P = B + ((-B) % block_b)
        if P != B:
            args = [jnp.pad(a, [(0, P - B)] + [(0, 0)] * (a.ndim - 1))
                    for a in args]
        if tile is None:
            tile = default_tile(Lx, Ly, d, block_b)
        dist, hit, pruned = wavefront_pallas(
            *args, mode=mode, Lx=Lx, Ly=Ly, d=d, block_b=block_b,
            interpret=interpret, tile=tile)
        return KernelOut(dist[:B], hit[:B], pruned[:B])

    # -- host path (cached jit) ----------------------------------------------

    def batch(self, xs, ys, lx=None, ly=None, eps=None, *,
              block_b: int = 8, interpret: Optional[bool] = None,
              exec: Optional[str] = None,
              tile: Optional[int] = None) -> KernelOut:
        """Host entry: numpy in/out, shapes padded and jit-cached.

        ``lx``/``ly`` may mix length buckets freely; operands are trimmed
        to the max actual lengths and the batch padded to a power of two so
        the number of distinct compiled shapes stays bounded.  ``exec`` /
        ``tile`` select the wavefront execution mode and Pallas band depth
        (see :meth:`device_call`); both resolve to static values *before*
        the cache lookup, so each (shape, exec, tile) class compiles once.
        """
        xs = np.asarray(xs)
        ys = np.asarray(ys)
        B = len(xs)
        if B == 0:
            z = np.zeros((0,), np.float32)
            return KernelOut(z, z.astype(bool), z.astype(bool))
        if lx is None:
            lx = np.full(B, xs.shape[1], np.int32)
        else:
            lx = np.asarray(lx, np.int32)
            xs = xs[:, :max(int(lx.max()), 1)]
        if ly is None:
            ly = np.full(B, ys.shape[1], np.int32)
        else:
            ly = np.asarray(ly, np.int32)
            ys = ys[:, :max(int(ly.max()), 1)]
        eps_v = np.full(B, np.inf, np.float32) if eps is None else \
            np.broadcast_to(np.asarray(eps, np.float32), (B,))
        interpret = resolve_interpret(interpret)
        if self.kind == "wavefront":
            exec_mode = resolve_exec(exec)
            if exec_mode == "pallas" and tile is None:
                dim = xs.shape[2] if xs.ndim == 3 else 1
                tile = default_tile(xs.shape[1], ys.shape[1], dim, block_b)
            if exec_mode == "scan":
                tile = None  # scan has no banding: one cache entry per shape
        else:
            exec_mode, tile = None, None  # elementwise/envelope: pure jnp

        P = _pad_pow2(max(B, block_b))
        fn = self._cached(xs, ys, P, block_b, interpret, exec_mode, tile)
        d, h, p = fn(_pad_rows(xs, P), _pad_rows(ys, P), _pad_rows(lx, P),
                     _pad_rows(ly, P), _pad_rows(eps_v, P))
        STATS["calls"] += 1
        return KernelOut(np.asarray(d)[:B], np.asarray(h)[:B],
                         np.asarray(p)[:B])

    def _cached(self, xs, ys, P, block_b, interpret, exec_mode=None,
                tile=None):
        key = (self.name, xs.shape[1:], str(xs.dtype), ys.shape[1:],
               str(ys.dtype), P, block_b, interpret, exec_mode, tile)
        fn = _JIT_CACHE.get(key)
        if fn is None:
            spec = self

            def traced(xs, ys, lx, ly, eps):
                STATS["traces"] += 1  # python side effect: runs per (re)trace
                return spec.device_call(xs, ys, lx, ly, eps,
                                        block_b=block_b, interpret=interpret,
                                        exec=exec_mode, tile=tile)

            fn = jax.jit(traced)
            _JIT_CACHE[key] = fn
        return fn


_KERNELS: Dict[str, KernelSpec] = {}
for _name, _mode in MODE_OF_NAME.items():
    _KERNELS[_name] = KernelSpec(name=_name, kind="wavefront", mode=_mode)
for _name in ("euclidean", "hamming"):
    _KERNELS[_name] = KernelSpec(name=_name, kind="elementwise")
# LB-cascade tier-1 envelope kernels: one per alignment distance with a
# registered envelope bound (levenshtein's length bound is already exact
# at tier 0, and token boxes are meaningless — no lb:levenshtein).
for _name in ("dtw", "erp", "frechet"):
    _KERNELS[f"lb:{_name}"] = KernelSpec(
        name=f"lb:{_name}", kind="envelope", mode=MODE_OF_NAME[_name])


def has(name: str) -> bool:
    return name in _KERNELS


def has_envelope(name: str) -> bool:
    """Whether distance ``name`` has a device tier-1 envelope kernel."""
    return f"lb:{name}" in _KERNELS


def get_envelope(name: str) -> KernelSpec:
    return get(f"lb:{name}")


def get(name: str) -> KernelSpec:
    if name not in _KERNELS:
        raise KeyError(
            f"no device kernel for distance {name!r}; have {sorted(_KERNELS)}")
    return _KERNELS[name]


def spec_for_mode(mode: str) -> KernelSpec:
    """Look up a wavefront spec by DP mode (``dtw``/``erp``/``dfd``/``lev``)."""
    if mode not in NAME_OF_MODE:
        raise KeyError(f"unknown wavefront mode {mode!r}")
    return get(NAME_OF_MODE[mode])


def names():
    return sorted(_KERNELS)
