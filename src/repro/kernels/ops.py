"""Jitted public wrappers around the Pallas kernels.

Layout preparation (reversal, padding, border construction) happens here in
cheap O(L) jnp ops; the kernels consume pre-laid-out tiles.  On non-TPU
backends the kernels run in ``interpret=True`` mode (Python semantics of the
same kernel body), which is how this container validates them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.pairwise_l2 import pairwise_l2_pallas
from repro.kernels.wavefront import wavefront_pallas

MODES = _ref.MODES


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_batch(a, mult):
    B = a.shape[0]
    rem = (-B) % mult
    if rem == 0:
        return a
    pad = [(0, rem)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad)


def wavefront(xs, ys, mode: str, *, block_b: int = 8, interpret=None):
    """Batched fixed-length alignment distance.

    Args:
      xs: (B, Lx) int tokens for mode='lev', else (B, Lx[, d]) float series.
      ys: (B, Ly) / (B, Ly[, d]) likewise.
      mode: one of dtw | erp | dfd | lev.

    Returns: (B,) float32 distances.
    """
    assert mode in MODES, mode
    if interpret is None:
        interpret = not _on_tpu()
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    if mode == "lev":
        xs = xs.astype(jnp.float32)[..., None]
        ys = ys.astype(jnp.float32)[..., None]
    else:
        xs = xs.astype(jnp.float32)
        ys = ys.astype(jnp.float32)
        if xs.ndim == 2:
            xs, ys = xs[..., None], ys[..., None]
    B, Lx, d = xs.shape
    Ly = ys.shape[1]

    # x laid out so position i holds x[i-1]
    x_pad = jnp.pad(xs, ((0, 0), (1, 0), (0, 0)))
    # reversed y padded so that diagonal k reads window start Lx+1+Ly-k
    Ypad = 2 * Lx + Ly + 1
    y_rev = jnp.flip(ys, axis=1)
    y_rev_pad = jnp.pad(y_rev, ((0, 0), (Lx + 1, Ypad - (Lx + 1) - Ly), (0, 0)))

    if mode == "erp":
        gx = jnp.sqrt(jnp.maximum(jnp.sum(xs * xs, -1), 0.0))   # (B, Lx)
        gy = jnp.sqrt(jnp.maximum(jnp.sum(ys * ys, -1), 0.0))   # (B, Ly)
        gap_x = jnp.pad(gx, ((0, 0), (1, 0)))
        gy_rev = jnp.flip(gy, axis=1)
        gap_y_rev = jnp.pad(gy_rev, ((0, 0), (Lx + 1, Ypad - (Lx + 1) - Ly)))
        zero = jnp.zeros((B, 1), jnp.float32)
        border_col = jnp.concatenate([zero, jnp.cumsum(gx, 1)], axis=1)
        border_row = jnp.concatenate([zero, jnp.cumsum(gy, 1)], axis=1)
    else:
        gap_x = jnp.zeros((B, Lx + 1), jnp.float32)
        gap_y_rev = jnp.zeros((B, Ypad), jnp.float32)
        if mode == "lev":
            border_col = jnp.broadcast_to(
                jnp.arange(Lx + 1, dtype=jnp.float32)[None], (B, Lx + 1))
            border_row = jnp.broadcast_to(
                jnp.arange(Ly + 1, dtype=jnp.float32)[None], (B, Ly + 1))
        else:
            big = jnp.float32(3.4e37)
            border_col = jnp.full((B, Lx + 1), big).at[:, 0].set(0.0)
            border_row = jnp.full((B, Ly + 1), big).at[:, 0].set(0.0)

    args = [x_pad, y_rev_pad, gap_x, gap_y_rev, border_col, border_row]
    args = [_pad_batch(a, block_b) for a in args]
    out = wavefront_pallas(*args, mode=mode, Lx=Lx, Ly=Ly, d=d,
                           block_b=block_b, interpret=bool(interpret))
    return out[:B]


def wavefront_ref(xs, ys, mode: str):
    """Oracle with the ops-level dtype handling (see kernels/ref.py)."""
    return _ref.wavefront_ref(xs, ys, mode)


def pairwise_l2(x, y, *, bm: int = 128, bn: int = 128, interpret=None):
    """(M, d) x (N, d) -> (M, N) Euclidean distances via the tiled kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    M, N = x.shape[0], y.shape[0]
    xp = _pad_batch(x, bm)
    yp = _pad_batch(y, bn)
    out = pairwise_l2_pallas(xp, yp, bm=bm, bn=bn, interpret=bool(interpret))
    return out[:M, :N]


def pairwise_l2_ref(x, y):
    return _ref.pairwise_l2_ref(x, y)
