"""Jitted public wrappers around the Pallas kernels.

Since the kernel-registry refactor this module is a thin compatibility
shim: layout preparation, the interpret policy, and per-shape jit caching
all live in ``repro.kernels.registry`` (one cache for every caller — the
counter backend, the device query path, and these wrappers), and ragged /
fused-ε dispatch lives in ``repro.kernels.dispatch``.  On non-TPU backends
the kernels run in ``interpret=True`` mode (Python semantics of the same
kernel body), which is how this container validates them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels.pairwise_l2 import pairwise_l2_pallas

MODES = _ref.MODES


def _pad_batch(a, mult):
    B = a.shape[0]
    rem = (-B) % mult
    if rem == 0:
        return a
    pad = [(0, rem)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad)


def wavefront(xs, ys, mode: str, *, block_b: int = 8, interpret=None,
              lens_x=None, lens_y=None, eps=None, exec=None, tile=None):
    """Batched alignment distance through the kernel registry.

    Args:
      xs: (B, Lx) int tokens for mode='lev', else (B, Lx[, d]) float series.
      ys: (B, Ly) / (B, Ly[, d]) likewise.
      mode: one of dtw | erp | dfd | lev.
      lens_x, lens_y: optional per-row actual lengths (ragged batches).
      eps: optional fused-ε threshold (scalar or per-row).
      exec: wavefront execution mode (``pallas`` | ``scan``; None follows
        the registry's process-wide policy).
      tile: Pallas band depth (None: the registry's VMEM heuristic).

    Returns: (B,) float32 distances, or the full
    :class:`~repro.kernels.registry.KernelOut` when ``eps`` is given.
    """
    assert mode in MODES, mode
    spec = registry.spec_for_mode(mode)
    # lint: allow[acct-raw-kernel-call] -- compatibility wrapper: registry.STATS counts its calls/traces; callers (benchmarks, kernel tests) do their own accounting
    out = spec.batch(xs, ys, lens_x, lens_y, eps=eps, block_b=block_b,
                     interpret=interpret, exec=exec, tile=tile)
    return out if eps is not None else out.dist


def wavefront_ref(xs, ys, mode: str):
    """Oracle with the ops-level dtype handling (see kernels/ref.py)."""
    return _ref.wavefront_ref(xs, ys, mode)


def pairwise_l2(x, y, *, bm: int = 128, bn: int = 128, interpret=None):
    """(M, d) x (N, d) -> (M, N) Euclidean distances via the tiled kernel."""
    interpret = registry.resolve_interpret(interpret)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    M, N = x.shape[0], y.shape[0]
    xp = _pad_batch(x, bm)
    yp = _pad_batch(y, bn)
    out = pairwise_l2_pallas(xp, yp, bm=bm, bn=bn, interpret=bool(interpret))
    return out[:M, :N]


def pairwise_l2_ref(x, y):
    return _ref.pairwise_l2_ref(x, y)
