"""Packed ragged-bucket dispatch: one device call per frontier round.

The matching layer buckets query segments by length (§5: there are only
``2*lambda_0 + 1`` lengths), and before this module every engine round paid
one device dispatch *per length bucket*.  The packed dispatcher folds a
round's work across **all** buckets into one padded call:

* rows are segment-sorted by their ``(len_x, len_y)`` bucket (stable), so
  equal shapes sit contiguously and the bucket layout is deterministic;
* the bucket offsets of the sorted layout are recorded as static metadata
  (:class:`PackedMeta`) — diagnostics for the benchmarks and the hook for a
  future per-bucket grid split;
* operands are padded to the round's maximum lengths and handed to the
  kernel registry in ONE call; per-row actual lengths ride along, so the
  ragged wavefront kernel reads each row's answer off its own diagonal;
* results are scattered back to the caller's row order.

Padding rows added by the registry's power-of-two batch discipline never
reach the caller (sliced off device-side) and are never counted — eval
accounting stays with :class:`~repro.core.counter.CountedDistance`, which
counts requested rows only (the same positional-masking discipline PR 3
established for the device query path).

:data:`STATS` tracks what per-bucket dispatch would have cost
(``bucket_rounds``) against what packing actually paid (``dispatches``) —
``benchmarks/bench_kernels.py`` gates the collapse.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.kernels import registry


@dataclasses.dataclass(frozen=True)
class PackedMeta:
    """Static layout of one packed dispatch (sorted by bucket)."""
    #: ``(len_x, len_y, count)`` per contiguous bucket, in sorted order
    buckets: Tuple[Tuple[int, int, int], ...]
    #: row offset of each bucket in the sorted layout
    offsets: Tuple[int, ...]
    #: ``(shard, count)`` row provenance when the dispatch spans a fleet
    #: round (cross-shard frontier merge); None for single-source dispatches
    shard_rows: Optional[Tuple[Tuple[int, int], ...]] = None

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_shards(self) -> int:
        return len(self.shard_rows) if self.shard_rows else 1


@dataclasses.dataclass
class DispatchStats:
    """Cumulative packed-dispatch accounting (benchmarks read this)."""
    dispatches: int = 0     # packed device calls actually issued
    bucket_rounds: int = 0  # calls a per-bucket dispatcher would have issued
    rows: int = 0           # requested rows (excl. any padding)
    pruned: int = 0         # rows certified > eps before their last diagonal
    #: rows per fleet shard across cross-shard (round-based fleet) dispatches
    shard_rows: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: LB-cascade accounting per tier (``endpoint`` / ``envelope``): rows a
    #: tier's bound was evaluated on, and rows it certified ``> eps``.
    #: Requested rows only — the registry's pow2 batch padding is sliced
    #: off before any bound value reaches these counters.
    lb_rows: Dict[str, int] = dataclasses.field(default_factory=dict)
    lb_pruned: Dict[str, int] = dataclasses.field(default_factory=dict)
    last_meta: Optional[PackedMeta] = None

    def reset(self) -> None:
        self.dispatches = 0
        self.bucket_rounds = 0
        self.rows = 0
        self.pruned = 0
        self.shard_rows = {}
        self.lb_rows = {}
        self.lb_pruned = {}
        self.last_meta = None

    def note_lb(self, tier: str, rows: int, pruned: int) -> None:
        self.lb_rows[tier] = self.lb_rows.get(tier, 0) + int(rows)
        self.lb_pruned[tier] = self.lb_pruned.get(tier, 0) + int(pruned)


STATS = DispatchStats()


def pad_ragged_rows(rows):
    """Stack ragged rows into a zero-padded ``(N, W[, d])`` array.

    Returns ``(padded, lengths)`` — the one ragged-batch layout every
    packed caller (engine, fleet serving) shares."""
    lens = np.array([len(r) for r in rows], np.int64)
    out = np.zeros((len(rows), int(lens.max())) + rows[0].shape[1:],
                   rows[0].dtype)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return out, lens


def pack_meta(lx: np.ndarray, ly: np.ndarray
              ) -> Tuple[np.ndarray, PackedMeta]:
    """Stable bucket sort of rows by ``(len_x, len_y)``.

    Returns the sort order plus the static bucket metadata of the sorted
    layout."""
    order = np.lexsort((ly, lx))
    slx, sly = lx[order], ly[order]
    buckets, offsets = [], []
    start = 0
    for i in range(1, len(order) + 1):
        if i == len(order) or slx[i] != slx[start] or sly[i] != sly[start]:
            buckets.append((int(slx[start]), int(sly[start]), i - start))
            offsets.append(start)
            start = i
    return order, PackedMeta(tuple(buckets), tuple(offsets))


def packed_batch(name: str, xs, ys, lx=None, ly=None, *, eps=None,
                 block_b: int = 8, interpret: Optional[bool] = None,
                 exec: Optional[str] = None, tile: Optional[int] = None,
                 shards=None) -> registry.KernelOut:
    """ONE padded device call over every length bucket of a round.

    ``xs``/``ys`` are row-paired batches whose rows may come from different
    ``(len_x, len_y)`` buckets (``lx``/``ly`` carry the actual lengths);
    ``eps`` (scalar or per-row; +inf rows opt out) enables fused ε-pruning.
    ``exec``/``tile`` pick the wavefront execution mode and Pallas band
    depth (None: the registry's process-wide policy / VMEM heuristic).
    ``shards`` optionally carries per-row provenance (the fleet worker slot
    each row's candidate window lives on) when a round-based fleet query
    merges frontiers across shards — recorded in :data:`STATS` and
    :class:`PackedMeta` so the benches can show a fleet round really is one
    dispatch, not one per shard.  Results come back in the caller's row
    order as numpy arrays.
    """
    spec = registry.get(name)
    xs = np.asarray(xs)
    ys = np.asarray(ys)
    B = len(xs)
    if B == 0:
        z = np.zeros((0,), np.float32)
        return registry.KernelOut(z, z.astype(bool), z.astype(bool))
    lx = np.full(B, xs.shape[1], np.int64) if lx is None \
        else np.asarray(lx, np.int64)
    ly = np.full(B, ys.shape[1], np.int64) if ly is None \
        else np.asarray(ly, np.int64)
    eps_v = None if eps is None else \
        np.broadcast_to(np.asarray(eps, np.float32), (B,))

    order, meta = pack_meta(lx, ly)
    out = spec.batch(
        xs[order], ys[order], lx[order], ly[order],
        eps=None if eps_v is None else eps_v[order],
        block_b=block_b, interpret=interpret, exec=exec, tile=tile)

    inv = np.empty_like(order)
    inv[order] = np.arange(B)
    result = registry.KernelOut(out.dist[inv], out.hit[inv], out.pruned[inv])

    if shards is not None:
        sid, cnt = np.unique(np.asarray(shards, np.int64),
                             return_counts=True)
        for s, c in zip(sid, cnt):
            STATS.shard_rows[int(s)] = \
                STATS.shard_rows.get(int(s), 0) + int(c)
        meta = dataclasses.replace(
            meta, shard_rows=tuple((int(s), int(c))
                                   for s, c in zip(sid, cnt)))
    STATS.dispatches += 1
    STATS.bucket_rounds += meta.n_buckets
    STATS.rows += B
    STATS.pruned += int(result.pruned.sum())
    STATS.last_meta = meta
    return result


def packed_envelope(name: str, xs, ys, lx=None, ly=None, *, eps,
                    block_b: int = 8,
                    interpret: Optional[bool] = None) -> registry.KernelOut:
    """ONE elementwise envelope-bound call over a round's candidate rows.

    The ``lb:<name>`` KernelSpec is O(B*L) elementwise work (no wavefront),
    so rows need no bucket sort — per-row lengths mask the ragged tails
    directly.  Returns the bound in ``.dist`` (never BIG-masked), with
    ``.pruned`` marking rows whose bound certifies ``dist > eps``.  Tier
    accounting lands in :data:`STATS` (``lb_rows['envelope']`` /
    ``lb_pruned['envelope']``); the registry's pow2 batch padding is sliced
    off inside ``spec.batch`` so padding rows never reach the counters.
    """
    spec = registry.get_envelope(name)
    xs = np.asarray(xs)
    ys = np.asarray(ys)
    B = len(xs)
    if B == 0:
        z = np.zeros((0,), np.float32)
        return registry.KernelOut(z, z.astype(bool), z.astype(bool))
    lx = np.full(B, xs.shape[1], np.int64) if lx is None \
        else np.asarray(lx, np.int64)
    ly = np.full(B, ys.shape[1], np.int64) if ly is None \
        else np.asarray(ly, np.int64)
    eps_v = np.broadcast_to(np.asarray(eps, np.float32), (B,))
    out = spec.batch(xs, ys, lx, ly, eps=eps_v,
                     block_b=block_b, interpret=interpret)
    STATS.note_lb("envelope", B, int(out.pruned.sum()))
    return out
