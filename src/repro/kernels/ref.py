"""Pure-jnp oracles for the Pallas kernels.

``wavefront_ref`` evaluates the same fixed-length batched alignment DP the
kernel computes, via the generic anti-diagonal engine in
``repro.distances._wavefront`` (which is itself tested against row-major
numpy oracles), so the kernel test chain is:

    numpy row-major DP  ==  jnp wavefront engine  ==  Pallas kernel
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.distances._wavefront import BIG, l2_cost, neq_cost, wavefront_dp

MODES = ("dtw", "erp", "dfd", "lev")


def _combine_for(mode):
    if mode == "dtw":
        return lambda c, cu, cl, dd, du, dl: c + jnp.minimum(dd, jnp.minimum(du, dl))
    if mode == "erp":
        return lambda c, cu, cl, dd, du, dl: jnp.minimum(
            dd + c, jnp.minimum(du + cu, dl + cl))
    if mode == "dfd":
        return lambda c, cu, cl, dd, du, dl: jnp.maximum(
            c, jnp.minimum(dd, jnp.minimum(du, dl)))
    if mode == "lev":
        return lambda c, cu, cl, dd, du, dl: jnp.minimum(
            dd + c, jnp.minimum(du + 1.0, dl + 1.0))
    raise ValueError(mode)


def prepare(xs, ys, mode):
    """Common preprocessing: cost tile + borders + (erp) gap vectors."""
    if mode == "lev":
        xs = jnp.asarray(xs)
        ys = jnp.asarray(ys)
        cost = neq_cost(xs, ys)
        B, Lx = xs.shape
        Ly = ys.shape[1]
        gap_x = gap_y = None
        border_col = jnp.broadcast_to(
            jnp.arange(Lx + 1, dtype=jnp.float32)[None, :], (B, Lx + 1))
        border_row = jnp.broadcast_to(
            jnp.arange(Ly + 1, dtype=jnp.float32)[None, :], (B, Ly + 1))
        return cost, border_col, border_row, gap_x, gap_y
    xs = jnp.asarray(xs, jnp.float32)
    ys = jnp.asarray(ys, jnp.float32)
    if xs.ndim == 2:
        xs, ys = xs[..., None], ys[..., None]
    B, Lx = xs.shape[0], xs.shape[1]
    Ly = ys.shape[1]
    cost = jnp.minimum(l2_cost(xs, ys), BIG)
    if mode == "erp":
        # clamp gaps and border cumsums at BIG so long high-gap-mass series
        # cannot push the borders past the quasi-infinity sentinel (inf/NaN)
        gap_x = jnp.minimum(
            jnp.sqrt(jnp.maximum(jnp.sum(xs * xs, -1), 0.0)), BIG)
        gap_y = jnp.minimum(
            jnp.sqrt(jnp.maximum(jnp.sum(ys * ys, -1), 0.0)), BIG)
        zero = jnp.zeros((B, 1), jnp.float32)
        border_col = jnp.minimum(
            jnp.concatenate([zero, jnp.cumsum(gap_x, 1)], axis=1), BIG)
        border_row = jnp.minimum(
            jnp.concatenate([zero, jnp.cumsum(gap_y, 1)], axis=1), BIG)
    else:
        gap_x = gap_y = None
        border_col = jnp.full((B, Lx + 1), BIG, jnp.float32).at[:, 0].set(0.0)
        border_row = jnp.full((B, Ly + 1), BIG, jnp.float32).at[:, 0].set(0.0)
    return cost, border_col, border_row, gap_x, gap_y


def wavefront_ref(xs, ys, mode: str):
    """(B, L[, d]) x (B, L[, d]) -> (B,) full-length alignment distance."""
    assert mode in MODES, mode
    cost, bc, br, gx, gy = prepare(xs, ys, mode)
    B, Lx, Ly = cost.shape
    lx = jnp.full((B,), Lx, jnp.int32)
    ly = jnp.full((B,), Ly, jnp.int32)
    return wavefront_dp(cost, _combine_for(mode), bc, br, lx, ly,
                        gap_x=gx, gap_y=gy)


def pairwise_l2_ref(x, y):
    """(M, d) x (N, d) -> (M, N) Euclidean distance matrix."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    xn = jnp.sum(x * x, axis=1)
    yn = jnp.sum(y * y, axis=1)
    d2 = xn[:, None] + yn[None, :] - 2.0 * (x @ y.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))
