"""Pallas TPU kernel: batched anti-diagonal wavefront alignment DP.

This is the paper's compute hot spot (§5/§7 step 4: every query segment is
compared against every surviving database window under an O(l^2) alignment
distance).  The TPU-native schedule:

* the batch of independent DP problems rides the sublane axis — one grid
  cell owns a ``(block_b, L+1)`` wavefront held in VMEM/VREGs;
* the 2l diagonal steps are a ``fori_loop`` whose body is pure VPU work:
  two rolling diagonal buffers, an elementwise cost slice, min/add;
* the elementwise cost is computed **on the fly** from the x tile and a
  *flipped* y tile: cost of diagonal k is ``elem(x[i-1], y[k-i-1])`` which is
  a contiguous ``dynamic_slice`` of reversed-y — no gathers, no (L x L) cost
  tile in HBM, arithmetic intensity stays on-chip;
* borders (column j=0 / row i=0) are injected per step from precomputed
  border vectors (constant for DTW/DFD/Lev, gap cumsums for ERP).

Modes: ``dtw`` / ``erp`` / ``dfd`` / ``lev`` (paper's four alignment
distances).  Fixed (static) lengths per call — the matching layer buckets
query segments by length (there are only 2*lambda_0+1 lengths, §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e37  # python float: Pallas kernels must not capture traced constants


def _shift_right(v, fill):
    return jnp.concatenate([jnp.full_like(v[:, :1], fill), v[:, :-1]], axis=1)


def _make_kernel(mode: str, Lx: int, Ly: int, d: int):
    W = Lx + 1

    def kernel(x_ref, yr_ref, gx_ref, gyr_ref, bc_ref, br_ref, out_ref):
        x = x_ref[...]          # (Bt, W, d)   x[i] = x_orig[i-1]
        yr = yr_ref[...]        # (Bt, Ypad, d) reversed+padded y
        gx = gx_ref[...]        # (Bt, W)      ERP gap cost of x_i (else 0)
        gyr = gyr_ref[...]      # (Bt, Ypad)   reversed+padded ERP gap of y
        bc = bc_ref[...]        # (Bt, Lx+1)   border column D[i,0]
        br = br_ref[...]        # (Bt, Ly+1)   border row    D[0,j]
        Bt = x.shape[0]
        ii = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)

        diag0 = jnp.full((Bt, W), BIG, jnp.float32)
        diag0 = diag0.at[:, 0].set(bc[:, 0])
        dinit = jnp.full((Bt, W), BIG, jnp.float32)

        def body(k, carry):
            d1, d2 = carry  # diagonals k-1, k-2
            s = Lx + 1 + Ly - k  # start of the diagonal window in reversed y
            ysl = jax.lax.dynamic_slice(yr, (0, s, 0), (Bt, W, d))
            if mode == "lev":
                c = (jnp.sum(jnp.abs(x - ysl), axis=-1) > 0).astype(jnp.float32)
            else:
                c = jnp.sqrt(jnp.maximum(jnp.sum((x - ysl) ** 2, axis=-1), 0.0))
            dd = _shift_right(d2, BIG)
            du = _shift_right(d1, BIG)
            dl = d1
            if mode == "dtw":
                new = c + jnp.minimum(dd, jnp.minimum(du, dl))
            elif mode == "dfd":
                new = jnp.maximum(c, jnp.minimum(dd, jnp.minimum(du, dl)))
            elif mode == "lev":
                new = jnp.minimum(dd + c, jnp.minimum(du + 1.0, dl + 1.0))
            else:  # erp
                gy = jax.lax.dynamic_slice(gyr, (0, s), (Bt, W))
                new = jnp.minimum(dd + c, jnp.minimum(du + gx, dl + gy))
            # border column j = 0 lives at position i = k (while k <= Lx)
            colv = jax.lax.dynamic_slice(bc, (0, jnp.minimum(k, Lx)), (Bt, 1))
            new = jnp.where((ii == k) & (k <= Lx), colv, new)
            # border row i = 0 lives at position 0 (while k <= Ly)
            rowv = jax.lax.dynamic_slice(br, (0, jnp.minimum(k, Ly)), (Bt, 1))
            new = jnp.where(ii == 0, jnp.where(k <= Ly, rowv, BIG), new)
            # outside the valid band
            new = jnp.where((ii > k) | (ii < k - Ly), BIG, new)
            return (new, d1)

        d1, _ = jax.lax.fori_loop(1, Lx + Ly + 1, body, (diag0, dinit))
        out_ref[...] = d1[:, Lx:Lx + 1]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("mode", "Lx", "Ly", "d", "block_b", "interpret"))
def wavefront_pallas(x_pad, y_rev_pad, gap_x, gap_y_rev, border_col,
                     border_row, *, mode, Lx, Ly, d, block_b, interpret):
    """Run the kernel on pre-laid-out inputs; see ``ops.wavefront``."""
    B = x_pad.shape[0]
    Ypad = y_rev_pad.shape[1]
    grid = (B // block_b,)
    kernel = _make_kernel(mode, Lx, Ly, d)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, Lx + 1, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, Ypad, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, Lx + 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, Ypad), lambda b: (b, 0)),
            pl.BlockSpec((block_b, Lx + 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, Ly + 1), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(x_pad, y_rev_pad, gap_x, gap_y_rev, border_col, border_row)
    return out[:, 0]
