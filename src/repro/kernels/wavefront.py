"""Pallas TPU kernel: batched anti-diagonal wavefront alignment DP,
VMEM-blocked into diagonal bands.

This is the paper's compute hot spot (§5/§7 step 4: every query segment is
compared against every surviving database window under an O(l^2) alignment
distance).  The TPU-native schedule:

* the batch of independent DP problems rides the sublane axis — one grid
  cell owns a ``(block_b, L+1)`` wavefront held in VMEM/VREGs;
* the ``2l`` diagonal steps are cut into **bands** of ``tile`` consecutive
  anti-diagonals; the grid is ``(batch block, band)`` and each cell runs a
  ``fori_loop`` over its band's diagonals — pure VPU work: two rolling
  diagonal buffers, an elementwise cost slice, min/add;
* the elementwise cost is computed **on the fly** from the x tile and a
  *flipped* y tile: cost of diagonal k is ``elem(x[i-1], y[k-i-1])`` which
  is a contiguous ``dynamic_slice`` of reversed-y — no gathers, no (L x L)
  cost tile in HBM, arithmetic intensity stays on-chip;
* per band, only that band's ``(Lx + tile)``-wide window of reversed-y is
  staged (``band_layout`` pre-gathers the overlapping windows, since a
  BlockSpec index map can only address multiples of the block shape), so
  the VMEM working set is fixed by the tile, not the segment length;
* the two carry diagonals, the per-row answer, and the fused-ε liveness
  certificate are handed between bands through VMEM scratch accumulators
  — TPU grids iterate sequentially (bands innermost), so band ``j`` reads
  exactly what band ``j-1`` wrote, and the final band materializes the
  outputs;
* borders (column j=0 / row i=0) are injected per step from precomputed
  border vectors (constant for DTW/DFD/Lev, gap cumsums for ERP).

Ragged batches: every row carries its own ``(len_x, len_y)`` (the packed
dispatcher concatenates all length buckets of a round into one call), and
the answer ``D[len_x, len_y]`` is recorded on the fly when diagonal
``len_x + len_y`` passes — whichever band that diagonal lands in.  Cells
outside a row's actual problem compute padding garbage that never feeds
its answer cell (DP dependencies only point to smaller indices).

Fused ε-pruning: each row also carries an ``eps`` threshold.  All four
distances are monotone along alignment paths (every combine adds a
nonnegative cost or takes a max), and any monotone path touches at least
one cell of any two consecutive diagonals, so ``min`` over the two rolling
diagonals exceeding ``eps`` is a certificate that the final distance does.
The kernel tracks that certificate per row step-by-step (bit-identical to
the untiled schedule), but a prune **verdict** is only ever emitted at a
band boundary — the certificate rides the scratch accumulators and the
``pruned`` output materializes with the final band, which preserves
soundness under any band split.  Rows with ``eps = +inf`` (the default
layout for value-consuming callers) disable both effects, so fused and
plain evaluation share one compiled kernel.

:func:`wavefront_scan` is the compiled ``lax.scan`` twin (the registry's
``exec="scan"`` mode): the same operand layout and the same per-diagonal
update (:func:`_make_step` is the single source of the DP math for every
execution mode), scanned over diagonals as one XLA while loop — the
measured win on CPU CI, while the Pallas path targets TPU.

Modes: ``dtw`` / ``erp`` / ``dfd`` / ``lev`` (paper's four alignment
distances).  Per-call padded shapes and the band tile are static; the
registry (``kernels/registry.py``) owns the jit cache over them.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 3.4e37  # python float: Pallas kernels must not capture traced constants


def _shift_right(v, fill):
    return jnp.concatenate([jnp.full_like(v[:, :1], fill), v[:, :-1]], axis=1)


def _make_step(mode: str, Lx: int, Ly: int):
    """One anti-diagonal DP update — the single source of the per-step math.

    Every execution mode (tiled Pallas, interpret-mode Pallas, compiled
    scan) calls this exact closure, so their results are bit-identical and
    the parity gates compare equality, not tolerance.  ``carry`` is
    ``(d1, d2, res, alive)``: the two rolling diagonals, the recorded
    answers, and the fused-ε liveness mask (f32 0/1 so it can ride VMEM
    scratch).  ``ysl``/``gy`` are diagonal ``k``'s reversed-y window and
    ERP gap window, already sliced by the caller (full-layout or band-tile
    offsets — the only thing that differs between execution modes).
    """

    def step(k, carry, x, ysl, gx, gy, bc, br, lx, target, eps, ii):
        d1, d2, res, alive = carry  # diagonals k-1, k-2
        Bt = x.shape[0]
        if mode == "lev":
            c = (jnp.sum(jnp.abs(x - ysl), axis=-1) > 0).astype(jnp.float32)
        else:
            c = jnp.sqrt(jnp.maximum(jnp.sum((x - ysl) ** 2, axis=-1), 0.0))
            c = jnp.minimum(c, BIG)
        dd = _shift_right(d2, BIG)
        du = _shift_right(d1, BIG)
        dl = d1
        if mode == "dtw":
            new = c + jnp.minimum(dd, jnp.minimum(du, dl))
        elif mode == "dfd":
            new = jnp.maximum(c, jnp.minimum(dd, jnp.minimum(du, dl)))
        elif mode == "lev":
            new = jnp.minimum(dd + c, jnp.minimum(du + 1.0, dl + 1.0))
        else:  # erp
            new = jnp.minimum(dd + c, jnp.minimum(du + gx, dl + gy))
        # clamp: sums of quasi-infinities must stay quasi-infinite, never
        # run off to float32 inf/NaN (long high-gap-mass series)
        new = jnp.minimum(new, BIG)
        # border column j = 0 lives at position i = k (while k <= Lx)
        colv = jax.lax.dynamic_slice(bc, (0, jnp.minimum(k, Lx)), (Bt, 1))
        new = jnp.where((ii == k) & (k <= Lx), colv, new)
        # border row i = 0 lives at position 0 (while k <= Ly)
        rowv = jax.lax.dynamic_slice(br, (0, jnp.minimum(k, Ly)), (Bt, 1))
        new = jnp.where(ii == 0, jnp.where(k <= Ly, rowv, BIG), new)
        # outside the valid band
        new = jnp.where((ii > k) | (ii < k - Ly), BIG, new)
        # record each row's answer when its target diagonal passes
        val = jnp.sum(jnp.where(ii == lx, new, 0.0), axis=1, keepdims=True)
        res = jnp.where(target == k, val, res)
        # fused ε certificate: every monotone path touches one of any two
        # consecutive diagonals, so both exceeding eps bounds the final
        rowmin = jnp.min(jnp.minimum(new, d1), axis=1, keepdims=True)
        ok = ((rowmin <= eps) | (k > target)).astype(jnp.float32)
        return (new, d1, res, alive * ok)

    return step


def band_layout(y_rev_pad, Lx: int, Ly: int, tile: int):
    """Pre-gather the per-band overlapping reversed-y windows.

    Band ``j`` (diagonals ``j*tile+1 .. (j+1)*tile``) reads reversed-y
    window starts ``s(k) = Lx+1+Ly-k`` over ``tile`` consecutive diagonals,
    i.e. the ``(Lx + tile)``-wide stretch starting at
    ``o_j = Lx+1+Ly-(j+1)*tile``.  A BlockSpec index map can only address
    multiples of the block shape, so overlapping stride-``tile`` windows of
    width ``Lx + tile`` are not expressible directly — instead the bands
    are gathered side by side into a ``(B, nbands*(Lx+tile)[, d])`` operand
    whose ``j``-th slab is band ``j``'s tile, and the kernel's in-band
    dynamic-slice offset for diagonal ``k`` is ``(j+1)*tile - k``
    (``tile-1-t`` for the band-local step index ``t``).

    Late bands clip below index 0; clipped positions are only ever read by
    DP cells outside the valid band, whose values the kernel overwrites
    with borders or the BIG sentinel before they can feed any answer.
    """
    Ypad = y_rev_pad.shape[1]
    K = Lx + Ly
    nbands = -(-K // tile)
    Wb = Lx + tile
    w = jnp.arange(Wb)
    o = Lx + 1 + Ly - (jnp.arange(nbands) + 1) * tile
    idx = jnp.clip(o[:, None] + w[None, :], 0, Ypad - 1).reshape(-1)
    return jnp.take(y_rev_pad, idx, axis=1)


def _make_kernel(mode: str, Lx: int, Ly: int, d: int, tile: int,
                 nbands: int):
    W = Lx + 1
    K = Lx + Ly
    step = _make_step(mode, Lx, Ly)

    def kernel(x_ref, yb_ref, gx_ref, gyb_ref, bc_ref, br_ref, lens_ref,
               eps_ref, out_ref, hit_ref, prune_ref,
               d1_ref, d2_ref, res_ref, alive_ref):
        x = x_ref[...]          # (Bt, W, d)   x[i] = x_orig[i-1]
        yb = yb_ref[...]        # (Bt, Lx+tile, d) this band's reversed-y tile
        gx = gx_ref[...]        # (Bt, W)      ERP gap cost of x_i (else 0)
        gyb = gyb_ref[...]      # (Bt, Lx+tile) banded reversed ERP gap of y
        bc = bc_ref[...]        # (Bt, Lx+1)   border column D[i,0]
        br = br_ref[...]        # (Bt, Ly+1)   border row    D[0,j]
        lens = lens_ref[...]    # (Bt, 2)      int32 actual (len_x, len_y)
        eps = eps_ref[...]      # (Bt, 1)      fused threshold (+inf = off)
        Bt = x.shape[0]
        lx = lens[:, 0:1]
        target = lx + lens[:, 1:2]   # diagonal holding D[len_x, len_y]
        ii = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
        j = pl.program_id(1)

        # band 0 seeds the carry scratch; later bands inherit band j-1's
        # (TPU grids iterate sequentially with bands innermost, and the
        # scratch accumulators persist across a batch block's grid cells)
        @pl.when(j == 0)
        def _init():
            diag0 = jnp.full((Bt, W), BIG,
                             jnp.float32).at[:, 0].set(bc[:, 0])
            d1_ref[...] = diag0
            d2_ref[...] = jnp.full((Bt, W), BIG, jnp.float32)
            res_ref[...] = jnp.where(target == 0, diag0[:, 0:1], BIG)
            alive_ref[...] = jnp.ones((Bt, 1), jnp.float32)

        def body(t, carry):
            k = j * tile + 1 + t
            # diagonal k's window inside this band's tile (see band_layout)
            off = tile - 1 - t
            ysl = jax.lax.dynamic_slice(yb, (0, off, 0), (Bt, W, d))
            if mode == "erp":
                gy = jax.lax.dynamic_slice(gyb, (0, off), (Bt, W))
            else:
                gy = gx
            out = step(k, carry, x, ysl, gx, gy, bc, br, lx, target, eps,
                       ii)
            # the last band may be ragged: steps past diagonal K are no-ops
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(k <= K, n, o), out, carry)

        carry = (d1_ref[...], d2_ref[...], res_ref[...], alive_ref[...])
        d1, d2, res, alive = jax.lax.fori_loop(0, tile, body, carry)
        d1_ref[...] = d1
        d2_ref[...] = d2
        res_ref[...] = res
        alive_ref[...] = alive

        # prune verdicts are only emitted at a band boundary — here, the
        # final one; the certificate itself rides the scratch accumulator
        @pl.when(j == nbands - 1)
        def _emit():
            hit = res <= eps
            out_ref[...] = jnp.where(hit, res, BIG)
            hit_ref[...] = hit.astype(jnp.float32)
            prune_ref[...] = 1.0 - alive

    return kernel


def wavefront_pallas(x_pad, y_rev_pad, gap_x, gap_y_rev, border_col,
                     border_row, lens, eps, *, mode, Lx, Ly, d, block_b,
                     interpret, tile: Optional[int] = None):
    """Run the banded kernel on pre-laid-out inputs (traceable — the
    registry owns jit caching; see ``registry.KernelSpec.device_call``).

    ``tile`` is the band depth in anti-diagonals (static per shape; the
    registry's ``default_tile`` VMEM-budget heuristic picks it when None).
    ``tile >= Lx + Ly`` degenerates to a single band — the exact untiled
    schedule.  Returns ``(dist, hit, pruned)`` as (B,) float32 arrays:
    masked distances (``BIG`` where the verdict is a miss), the hit mask,
    and the early-prune certificate mask.
    """
    B = x_pad.shape[0]
    W = Lx + 1
    K = Lx + Ly
    T = K if tile is None else max(1, min(int(tile), K))
    nbands = -(-K // T)
    Wb = Lx + T
    y_bands = band_layout(y_rev_pad, Lx, Ly, T)    # (B, nbands*Wb, d)
    gy_bands = band_layout(gap_y_rev, Lx, Ly, T)   # (B, nbands*Wb)
    grid = (B // block_b, nbands)
    kernel = _make_kernel(mode, Lx, Ly, d, T, nbands)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, W, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((block_b, Wb, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((block_b, W), lambda b, j: (b, 0)),
            pl.BlockSpec((block_b, Wb), lambda b, j: (b, j)),
            pl.BlockSpec((block_b, Lx + 1), lambda b, j: (b, 0)),
            pl.BlockSpec((block_b, Ly + 1), lambda b, j: (b, 0)),
            pl.BlockSpec((block_b, 2), lambda b, j: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, j: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, W), jnp.float32),   # carry diagonal k-1
            pltpu.VMEM((block_b, W), jnp.float32),   # carry diagonal k-2
            pltpu.VMEM((block_b, 1), jnp.float32),   # recorded answers
            pltpu.VMEM((block_b, 1), jnp.float32),   # fused-ε liveness
        ],
        interpret=interpret,
    )(x_pad, y_bands, gap_x, gy_bands, border_col, border_row, lens, eps)
    dist, hit, pruned = outs
    return dist[:, 0], hit[:, 0] > 0, pruned[:, 0] > 0


def wavefront_scan(x_pad, y_rev_pad, gap_x, gap_y_rev, border_col,
                   border_row, lens, eps, *, mode, Lx, Ly, d):
    """Compiled ``lax.scan`` wavefront — the registry's ``exec="scan"``
    execution mode.

    Identical operand layout and per-diagonal update as the Pallas kernel
    (:func:`_make_step`), but scanned over the ``Lx+Ly`` diagonals as one
    XLA while loop with a known trip count — no Pallas, no banding, no
    batch blocking.  On CPU CI this is the measured device-path win (the
    interpret-mode Pallas emulation is parity theater); on TPU the banded
    Pallas kernel owns the hot path.  Returns the same ``(dist, hit,
    pruned)`` triple, bit-identical to the Pallas schedules.
    """
    B = x_pad.shape[0]
    W = Lx + 1
    lx = lens[:, 0:1]
    target = lx + lens[:, 1:2]
    ii = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    step = _make_step(mode, Lx, Ly)

    diag0 = jnp.full((B, W), BIG, jnp.float32).at[:, 0].set(border_col[:, 0])
    carry0 = (diag0,
              jnp.full((B, W), BIG, jnp.float32),
              jnp.where(target == 0, diag0[:, 0:1], BIG),
              jnp.ones((B, 1), jnp.float32))

    def body(carry, k):
        s = Lx + 1 + Ly - k  # start of the diagonal window in reversed y
        ysl = jax.lax.dynamic_slice(y_rev_pad, (0, s, 0), (B, W, d))
        if mode == "erp":
            gy = jax.lax.dynamic_slice(gap_y_rev, (0, s), (B, W))
        else:
            gy = gap_x
        return step(k, carry, x_pad, ysl, gap_x, gy, border_col,
                    border_row, lx, target, eps, ii), None

    (_, _, res, alive), _ = jax.lax.scan(
        body, carry0, jnp.arange(1, Lx + Ly + 1))
    hit = res <= eps
    dist = jnp.where(hit, res, BIG)
    return dist[:, 0], hit[:, 0] > 0, alive[:, 0] < 0.5
