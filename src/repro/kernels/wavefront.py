"""Pallas TPU kernel: batched anti-diagonal wavefront alignment DP.

This is the paper's compute hot spot (§5/§7 step 4: every query segment is
compared against every surviving database window under an O(l^2) alignment
distance).  The TPU-native schedule:

* the batch of independent DP problems rides the sublane axis — one grid
  cell owns a ``(block_b, L+1)`` wavefront held in VMEM/VREGs;
* the 2l diagonal steps are a ``fori_loop`` whose body is pure VPU work:
  two rolling diagonal buffers, an elementwise cost slice, min/add;
* the elementwise cost is computed **on the fly** from the x tile and a
  *flipped* y tile: cost of diagonal k is ``elem(x[i-1], y[k-i-1])`` which is
  a contiguous ``dynamic_slice`` of reversed-y — no gathers, no (L x L) cost
  tile in HBM, arithmetic intensity stays on-chip;
* borders (column j=0 / row i=0) are injected per step from precomputed
  border vectors (constant for DTW/DFD/Lev, gap cumsums for ERP).

Ragged batches: every row carries its own ``(len_x, len_y)`` (the packed
dispatcher concatenates all length buckets of a round into one call), and
the answer ``D[len_x, len_y]`` is recorded on the fly when diagonal
``len_x + len_y`` passes.  Cells outside a row's actual problem compute
padding garbage that never feeds its answer cell (DP dependencies only
point to smaller indices).

Fused ε-pruning: each row also carries an ``eps`` threshold.  All four
distances are monotone along alignment paths (every combine adds a
nonnegative cost or takes a max), and any monotone path touches at least
one cell of any two consecutive diagonals, so ``min`` over the two rolling
diagonals exceeding ``eps`` is a certificate that the final distance does.
The kernel tracks that certificate per row (the ``pruned`` output) and only
materializes distances for rows whose verdict is a hit — pruned and missed
rows ship the ``BIG`` sentinel plus a 0 in the ``hit`` mask.  Passing
``eps = +inf`` (the default layout for value-consuming callers) disables
both effects, so fused and plain evaluation share one compiled kernel.

Modes: ``dtw`` / ``erp`` / ``dfd`` / ``lev`` (paper's four alignment
distances).  Per-call padded shapes are static; the registry
(``kernels/registry.py``) owns the jit cache over them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e37  # python float: Pallas kernels must not capture traced constants


def _shift_right(v, fill):
    return jnp.concatenate([jnp.full_like(v[:, :1], fill), v[:, :-1]], axis=1)


def _make_kernel(mode: str, Lx: int, Ly: int, d: int):
    W = Lx + 1

    def kernel(x_ref, yr_ref, gx_ref, gyr_ref, bc_ref, br_ref, lens_ref,
               eps_ref, out_ref, hit_ref, prune_ref):
        x = x_ref[...]          # (Bt, W, d)   x[i] = x_orig[i-1]
        yr = yr_ref[...]        # (Bt, Ypad, d) reversed+padded y
        gx = gx_ref[...]        # (Bt, W)      ERP gap cost of x_i (else 0)
        gyr = gyr_ref[...]      # (Bt, Ypad)   reversed+padded ERP gap of y
        bc = bc_ref[...]        # (Bt, Lx+1)   border column D[i,0]
        br = br_ref[...]        # (Bt, Ly+1)   border row    D[0,j]
        lens = lens_ref[...]    # (Bt, 2)      int32 actual (len_x, len_y)
        eps = eps_ref[...]      # (Bt, 1)      fused threshold (+inf = off)
        Bt = x.shape[0]
        lx = lens[:, 0:1]
        target = lx + lens[:, 1:2]   # diagonal holding D[len_x, len_y]
        ii = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)

        diag0 = jnp.full((Bt, W), BIG, jnp.float32)
        diag0 = diag0.at[:, 0].set(bc[:, 0])
        dinit = jnp.full((Bt, W), BIG, jnp.float32)
        res0 = jnp.where(target == 0, diag0[:, 0:1], BIG)
        alive0 = jnp.ones((Bt, 1), jnp.bool_)

        def body(k, carry):
            d1, d2, res, alive = carry  # diagonals k-1, k-2
            s = Lx + 1 + Ly - k  # start of the diagonal window in reversed y
            ysl = jax.lax.dynamic_slice(yr, (0, s, 0), (Bt, W, d))
            if mode == "lev":
                c = (jnp.sum(jnp.abs(x - ysl), axis=-1) > 0).astype(jnp.float32)
            else:
                c = jnp.sqrt(jnp.maximum(jnp.sum((x - ysl) ** 2, axis=-1), 0.0))
                c = jnp.minimum(c, BIG)
            dd = _shift_right(d2, BIG)
            du = _shift_right(d1, BIG)
            dl = d1
            if mode == "dtw":
                new = c + jnp.minimum(dd, jnp.minimum(du, dl))
            elif mode == "dfd":
                new = jnp.maximum(c, jnp.minimum(dd, jnp.minimum(du, dl)))
            elif mode == "lev":
                new = jnp.minimum(dd + c, jnp.minimum(du + 1.0, dl + 1.0))
            else:  # erp
                gy = jax.lax.dynamic_slice(gyr, (0, s), (Bt, W))
                new = jnp.minimum(dd + c, jnp.minimum(du + gx, dl + gy))
            # clamp: sums of quasi-infinities must stay quasi-infinite, never
            # run off to float32 inf/NaN (long high-gap-mass series)
            new = jnp.minimum(new, BIG)
            # border column j = 0 lives at position i = k (while k <= Lx)
            colv = jax.lax.dynamic_slice(bc, (0, jnp.minimum(k, Lx)), (Bt, 1))
            new = jnp.where((ii == k) & (k <= Lx), colv, new)
            # border row i = 0 lives at position 0 (while k <= Ly)
            rowv = jax.lax.dynamic_slice(br, (0, jnp.minimum(k, Ly)), (Bt, 1))
            new = jnp.where(ii == 0, jnp.where(k <= Ly, rowv, BIG), new)
            # outside the valid band
            new = jnp.where((ii > k) | (ii < k - Ly), BIG, new)
            # record each row's answer when its target diagonal passes
            val = jnp.sum(jnp.where(ii == lx, new, 0.0), axis=1, keepdims=True)
            res = jnp.where(target == k, val, res)
            # fused ε certificate: every monotone path touches one of any two
            # consecutive diagonals, so both exceeding eps bounds the final
            rowmin = jnp.min(jnp.minimum(new, d1), axis=1, keepdims=True)
            alive = alive & ((rowmin <= eps) | (k > target))
            return (new, d1, res, alive)

        _, _, res, alive = jax.lax.fori_loop(
            1, Lx + Ly + 1, body, (diag0, dinit, res0, alive0))
        hit = res <= eps
        out_ref[...] = jnp.where(hit, res, BIG)
        hit_ref[...] = hit.astype(jnp.float32)
        prune_ref[...] = (~alive).astype(jnp.float32)

    return kernel


def wavefront_pallas(x_pad, y_rev_pad, gap_x, gap_y_rev, border_col,
                     border_row, lens, eps, *, mode, Lx, Ly, d, block_b,
                     interpret):
    """Run the kernel on pre-laid-out inputs (traceable — the registry owns
    jit caching; see ``registry.KernelSpec.device_call``).

    Returns ``(dist, hit, pruned)`` as (B,) float32 arrays: masked
    distances (``BIG`` where the verdict is a miss), the hit mask, and the
    early-prune certificate mask.
    """
    B = x_pad.shape[0]
    Ypad = y_rev_pad.shape[1]
    grid = (B // block_b,)
    kernel = _make_kernel(mode, Lx, Ly, d)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, Lx + 1, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, Ypad, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, Lx + 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, Ypad), lambda b: (b, 0)),
            pl.BlockSpec((block_b, Lx + 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, Ly + 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, 2), lambda b: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x_pad, y_rev_pad, gap_x, gap_y_rev, border_col, border_row, lens, eps)
    dist, hit, pruned = outs
    return dist[:, 0], hit[:, 0] > 0, pruned[:, 0] > 0
