"""Pallas TPU kernel: MXU-tiled pairwise Euclidean distance matrix.

Used by the embedding-retrieval path: filtering M query windows against N
database windows under L2 is ``||x||^2 + ||y||^2 - 2 x @ y.T`` — one MXU
matmul per (128, 128) output tile with both operand tiles resident in VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry


def _kernel(x_ref, y_ref, out_ref):
    x = x_ref[...]  # (bm, d)
    y = y_ref[...]  # (bn, d)
    xn = jnp.sum(x * x, axis=1, keepdims=True)          # (bm, 1)
    yn = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, bn)
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (bm, bn) on the MXU
    d2 = xn + yn - 2.0 * xy
    out_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


def pairwise_l2_pallas(x, y, *, bm: int = 128, bn: int = 128,
                       interpret: Optional[bool] = None):
    """(M, d) x (N, d) -> (M, N); M, N padded to tile multiples by ops.py.

    ``interpret=None`` resolves through the registry's single process-wide
    interpret policy (``registry.default_interpret()``) — resolution
    happens *outside* the jitted inner so a later policy change (the
    ``set_default_interpret`` hook, the hardware lane) is never shadowed
    by a stale jit cache entry keyed on None.
    """
    return _pairwise_l2_jit(x, y, bm=bm, bn=bn,
                            interpret=registry.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def _pairwise_l2_jit(x, y, *, bm, bn, interpret):
    M, d = x.shape
    N = y.shape[0]
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
