"""Pallas TPU kernel: MXU-tiled pairwise Euclidean distance matrix.

Used by the embedding-retrieval path: filtering M query windows against N
database windows under L2 is ``||x||^2 + ||y||^2 - 2 x @ y.T`` — one MXU
matmul per (128, 128) output tile with both operand tiles resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, out_ref):
    x = x_ref[...]  # (bm, d)
    y = y_ref[...]  # (bn, d)
    xn = jnp.sum(x * x, axis=1, keepdims=True)          # (bm, 1)
    yn = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, bn)
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (bm, bn) on the MXU
    d2 = xn + yn - 2.0 * xy
    out_ref[...] = jnp.sqrt(jnp.maximum(d2, 0.0))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_l2_pallas(x, y, *, bm=128, bn=128, interpret=True):
    """(M, d) x (N, d) -> (M, N); M, N padded to tile multiples by ops.py."""
    M, d = x.shape
    N = y.shape[0]
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
