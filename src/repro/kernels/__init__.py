# The unified device-kernel substrate: one KernelSpec per distance
# (registry.py: wavefront dtw/erp/dfd/lev + elementwise euclidean/hamming,
# one interpret policy, one per-shape jit cache), packed ragged-bucket
# dispatch (dispatch.py), the Pallas kernel bodies (wavefront.py,
# pairwise_l2.py), jnp oracles (ref.py), and thin compat wrappers (ops.py).
