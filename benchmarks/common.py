"""Shared benchmark utilities.  Every benchmark prints CSV rows
``name,us_per_call,derived`` and returns them as dicts for run.py."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def row(name: str, us_per_call: float, **derived) -> Dict:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{d}")
    return {"name": name, "us_per_call": us_per_call, **derived}


def timeit(fn: Callable, *args, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def mutate_queries(data: np.ndarray, n: int, seed: int = 0,
                   rate: float = 0.1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    qs = data[rng.integers(0, len(data), n)].copy()
    if data.dtype.kind in "iu":
        hi = int(data.max()) + 1
        flips = rng.random(qs.shape) < rate
        qs[flips] = rng.integers(0, hi, flips.sum())
    else:
        qs += rng.normal(scale=rate * np.std(data),
                         size=qs.shape).astype(qs.dtype)
    return qs
