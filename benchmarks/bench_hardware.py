"""Real-hardware kernel lane — compiled Pallas wall-clock (opt-in).

Every other suite runs the Pallas substrate in interpret mode so CPU CI
can gate COUNT metrics strictly; interpreter wall-clock is meaningless and
the suites say so.  This lane is the complement: it flips the registry's
process-wide policy to ``interpret=False`` and times the ACTUAL compiled
tiled wavefront on whatever accelerator backend is attached, next to the
compiled ``lax.scan`` twin on the same shapes.

Rules of the lane:

* **opt-in** — reached only via ``python -m benchmarks.run --hardware``
  (CI: the ``workflow_dispatch`` bench-hardware job);
* **self-skipping** — when ``jax.default_backend()`` is ``cpu`` there is
  no accelerator to time, so the lane prints one note and returns zero
  rows rather than pretending interpreter numbers are hardware numbers;
* **warn-only** — rows are wall-clock (machine-dependent), never added to
  ``BENCH_kernels.json``; ``compare.py`` ignores rows absent from the
  baseline, so this lane can never fail a strict-count gate;
* **still exact** — parity against the scan backend is asserted on every
  shape before a timing is recorded (a fast wrong kernel is not a row).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.kernels import registry

#: (mode, (B, Lx, Ly, d), tile) — tile=None exercises the VMEM-budget
#: heuristic; explicit tiles exercise multi-band carry hand-off at depth
SHAPES = [
    ("dtw", (32, 64, 64, 2), None),
    ("dtw", (32, 64, 64, 2), 16),
    ("erp", (32, 64, 64, 2), 16),
    ("lev", (32, 48, 48, None), 12),
]


def run(full: bool = False):
    import jax

    backend = jax.default_backend()
    if backend == "cpu":
        print("# hardware lane: no accelerator attached "
              "(jax.default_backend()=cpu) — skipping")
        return []

    out = []
    prev = registry.set_default_interpret(False)
    try:
        rs = np.random.default_rng(0)
        for mode, (B, Lx, Ly, d), tile in SHAPES:
            if d is None:
                xs = rs.integers(0, 8, (B, Lx))
                ys = rs.integers(0, 8, (B, Ly))
            else:
                xs = rs.normal(size=(B, Lx, d)).astype(np.float32)
                ys = rs.normal(size=(B, Ly, d)).astype(np.float32)
            spec = registry.get({"dtw": "dtw", "erp": "erp",
                                 "lev": "levenshtein"}[mode])
            lx = np.full(B, Lx, np.int64)
            ly = np.full(B, Ly, np.int64)
            eps = np.full(B, 2.0, np.float32)

            def pallas_call():
                return spec.batch(xs, ys, lx, ly, eps=eps,
                                  exec="pallas", tile=tile)

            def scan_call():
                return spec.batch(xs, ys, lx, ly, eps=eps, exec="scan")

            got = pallas_call()          # compile + parity before timing
            ref = scan_call()
            assert np.allclose(got.dist, ref.dist, rtol=1e-5, atol=1e-5), \
                f"{mode} tile={tile}: compiled kernel diverged from scan"
            assert (got.hit == ref.hit).all(), \
                f"{mode} tile={tile}: compiled kernel changed the hit set"

            dt = timeit(pallas_call) / B
            scan_dt = timeit(scan_call) / B
            t = "auto" if tile is None else tile
            out.append(row(
                f"hardware_{mode}_t{t}", dt,
                backend=backend, tile=t, rows=B,
                scan_us_per_row=round(scan_dt, 2)))
    finally:
        registry.set_default_interpret(prev)
    return out
