"""LB envelope-cascade benchmark — the tiered lower-bound gates.

Locks the PR's acceptance criterion as deterministic count metrics
(compared strict in CI against ``BENCH_bounds.json``):

* ``bounds_dtw_*`` / ``bounds_erp_*`` — linear-scan retrieval on TRAJ,
  where the cascade IS the pruning mechanism (every candidate is a
  verdict row).  Per eps the facade runs cascade-off, ``endpoint`` and
  ``envelope`` tiers; hit sets are asserted identical and the envelope
  tier's exact wavefront evaluations are gated at <= 0.7x the
  cascade-off count (the >= 30% drop).
* ``bounds_erp_refnet_*`` — diagnostic rows on the reference-net index:
  refnet descent frontiers are mostly EXACT rows (the distance value
  itself steers the traversal, so they opt out of LB pruning with an
  infinite fused eps) and the drop there is structurally small.
  Reported, not gated.
* ``bounds_packed_dtw`` — the device-fused path
  (``kernel_backend="pallas"``): the ``lb:dtw`` elementwise envelope
  spec screens each packed round before the wavefront call, and the
  dispatcher's per-tier ``lb_rows``/``lb_pruned`` accounting is
  reported (padding rows excluded by construction).
* ``bounds_envelope_warm_sweep`` — repeating a shape-stable envelope
  sweep through the kernel registry compiles nothing (``traces`` 0).
* ``bounds_roofline_*`` — arithmetic intensity of the elementwise
  ``lb:dtw`` spec vs the ``dtw`` wavefront spec at the same batch
  shape (``roofline.hlo_costs.kernel_cost_report``): the envelope
  screen is the VPU-friendly cheap stage, the DP the expensive one.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mutate_queries, row
from repro.kernels import dispatch as kernel_dispatch
from repro.kernels import registry
from repro.retrieval import RetrievalConfig, Retriever
from repro.roofline.hlo_costs import kernel_cost_report

#: acceptance gate — envelope-tier exact evals vs cascade-off exact evals
DROP_GATE = 0.7


def _cascade_cell(name, dist_name, data, ranges, nq, out, *, gate):
    r = Retriever.build(RetrievalConfig(dist_name, index="linear"), data)
    qs = mutate_queries(data, nq, seed=2)
    N = len(data)
    for eps in ranges:
        r.reset_counter()
        t0 = time.perf_counter()
        off = r.batch(qs).via("batched").range(eps)
        off_dt = (time.perf_counter() - t0) * 1e6 / nq
        out.append(row(
            f"{name}_eps{eps}_off", off_dt,
            evals_frac=round(off.stats["query"] / (nq * N), 4),
            dispatches=off.stats["dispatches"],
            rounds=off.stats["rounds"]))

        tier_evals = {}
        for tier in ("endpoint", "envelope"):
            r.reset_counter()
            t0 = time.perf_counter()
            res = r.batch(qs).via("batched").lb(tier).range(eps)
            dt = (time.perf_counter() - t0) * 1e6 / nq
            assert res.hits == off.hits, \
                f"{name} tier={tier} changed hit set at eps={eps}"
            assert res.stats["build"] == off.stats["build"], \
                f"{name} tier={tier} drifted build evals at eps={eps}"
            tier_evals[tier] = res.stats["query"]
            out.append(row(
                f"{name}_eps{eps}_{tier}", dt,
                evals_frac=round(res.stats["query"] / (nq * N), 4),
                lb_evals=res.stats["lb"],
                dispatches=res.stats["dispatches"],
                exact_drop=round(1 - res.stats["query"]
                                 / max(off.stats["query"], 1), 3),
                speedup=round(off_dt / max(dt, 1e-9), 2)))

        if gate:
            assert tier_evals["envelope"] <= DROP_GATE * off.stats["query"], (
                f"{name} eps={eps}: envelope tier kept "
                f"{tier_evals['envelope']}/{off.stats['query']} exact evals "
                f"(gate: <= {DROP_GATE:.0%})")


def _refnet_diagnostic(data, ranges, nq, out):
    cfg = RetrievalConfig("erp", eps_prime=2.0, bulk_build=False)
    r = Retriever.build(cfg, data)
    qs = mutate_queries(data, nq, seed=2)
    N = len(data)
    for eps in ranges:
        r.reset_counter()
        off = r.batch(qs).via("batched").range(eps)
        r.reset_counter()
        t0 = time.perf_counter()
        env = r.batch(qs).via("batched").lb("envelope").range(eps)
        dt = (time.perf_counter() - t0) * 1e6 / nq
        assert env.hits == off.hits, f"refnet envelope mismatch eps={eps}"
        out.append(row(
            f"bounds_erp_refnet_eps{eps}", dt,
            evals_frac=round(env.stats["query"] / (nq * N), 4),
            lb_evals=env.stats["lb"],
            exact_drop=round(1 - env.stats["query"]
                             / max(off.stats["query"], 1), 3)))


def run(full: bool = False):
    from repro.data import synthetic
    out = []
    n = 4000 if full else 1200
    nq = 20 if full else 8
    traj = synthetic.trajectories(n, seed=0)

    # -- gated cells: linear scan, cascade is the pruning mechanism --------
    _cascade_cell("bounds_dtw", "dtw", traj, [1.0, 2.0, 4.0], nq, out,
                  gate=True)
    _cascade_cell("bounds_erp", "erp", traj, [1.0, 2.0, 4.0], nq, out,
                  gate=True)

    # -- diagnostic: refnet frontiers are mostly EXACT rows ----------------
    _refnet_diagnostic(traj, [1.0, 2.0], nq, out)

    # -- device-fused path: envelope screen inside the packed round --------
    nd = 600 if full else 240
    nqd = 4
    ddata = traj[:nd]
    rp = Retriever.build(
        RetrievalConfig("dtw", index="linear", kernel_backend="pallas"),
        ddata)
    dqs = mutate_queries(ddata, nqd, seed=5)
    off = rp.batch(dqs).via("batched").range(2.0)
    kernel_dispatch.STATS.reset()
    t0 = time.perf_counter()
    env = rp.batch(dqs).via("batched").lb("envelope").range(2.0)
    dt = (time.perf_counter() - t0) * 1e6 / nqd
    assert env.hits == off.hits, "packed envelope cascade changed hit set"
    lb_rows = kernel_dispatch.STATS.lb_rows.get("envelope", 0)
    lb_pruned = kernel_dispatch.STATS.lb_pruned.get("envelope", 0)
    assert lb_rows > 0, "packed path never ran the envelope spec"
    out.append(row(
        "bounds_packed_dtw", dt,
        evals_frac=round(env.stats["query"] / (nqd * nd), 4),
        lb_rows=lb_rows, lb_pruned=lb_pruned,
        prune_rate=round(lb_pruned / max(lb_rows, 1), 3)))

    # -- trace discipline: shape-stable envelope sweeps compile nothing ----
    shapes = [("dtw", (16, 12, 2)), ("erp", (16, 12, 2)),
              ("frechet", (16, 12, 2))]

    def run_sweep():
        rs = np.random.default_rng(0)
        for dist_name, (B, L, d) in shapes:
            xs = rs.normal(size=(B, L, d)).astype(np.float32)
            ys = rs.normal(size=(B, L, d)).astype(np.float32)
            spec = registry.get_envelope(dist_name)
            spec.batch(xs, ys, eps=np.full(B, 1.0, np.float32),
                       interpret=True)

    run_sweep()                       # warm the cache
    before = registry.STATS["traces"]
    t0 = time.perf_counter()
    run_sweep()
    sweep_dt = (time.perf_counter() - t0) * 1e6 / len(shapes)
    retraces = registry.STATS["traces"] - before
    assert retraces == 0, f"envelope warm sweep retraced {retraces} kernels"
    out.append(row("bounds_envelope_warm_sweep", sweep_dt, traces=retraces))

    # -- roofline: elementwise screen vs wavefront DP at one batch shape ---
    B, L, d = 8, 24, 2
    rs = np.random.default_rng(0)
    xs = rs.normal(size=(B, L, d)).astype(np.float32)
    ys = rs.normal(size=(B, L, d)).astype(np.float32)
    lens = np.full(B, L, np.int32)
    epsv = np.full(B, 2.0, np.float32)
    env_spec = registry.get_envelope("dtw")
    wav_spec = registry.get("dtw")
    for label, spec in (("lb_dtw_elementwise", env_spec),
                        ("dtw_wavefront", wav_spec)):
        def fn(xs, ys, lx, ly, eps, _spec=spec):
            return _spec.device_call(xs, ys, lx, ly, eps, interpret=True)
        t0 = time.perf_counter()
        rep = kernel_cost_report(fn, xs, ys, lens, lens, epsv)
        dt = (time.perf_counter() - t0) * 1e6
        out.append(row(
            f"bounds_roofline_{label}", dt,
            flops=rep["flops"], bytes=rep["bytes"],
            arithmetic_intensity=round(rep["arithmetic_intensity"], 4),
            n_while=rep["n_while"]))
    return out
