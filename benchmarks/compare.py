"""Warn-only benchmark regression gate.

Compares a fresh ``reports/benchmarks.json`` against the checked-in
baseline (``BENCH_query.json``) row-by-row (matched on ``name``) and emits
GitHub Actions ``::warning::`` annotations for timing regressions and for
any increase in the paper's exact-evaluation fraction.  Always exits 0 —
the gate records the perf trajectory without blocking PRs (flip
``--strict`` once the fleet of CI runners is quiet enough to trust).

  python -m benchmarks.compare --baseline BENCH_query.json \
      --report reports/benchmarks.json [--tolerance 1.5] [--strict]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _rows_by_name(rows):
    return {r["name"]: r for r in rows if "name" in r}


def compare(baseline_rows, report_rows, tolerance: float):
    base = _rows_by_name(baseline_rows)
    rep = _rows_by_name(report_rows)
    warnings = []
    compared = 0
    for name, b in sorted(base.items()):
        r = rep.get(name)
        if r is None:
            continue
        compared += 1
        b_us, r_us = float(b["us_per_call"]), float(r["us_per_call"])
        if b_us > 0 and r_us > tolerance * b_us:
            warnings.append(
                f"{name}: {r_us:.1f}us vs baseline {b_us:.1f}us "
                f"({r_us / b_us:.2f}x, tolerance {tolerance:.2f}x)")
        for key in ("evals_frac", "dispatches"):
            if key in b and key in r and float(r[key]) > float(b[key]) * 1.01:
                warnings.append(
                    f"{name}: {key} rose {b[key]} -> {r[key]} "
                    "(pruning/batching regression)")
    return compared, warnings


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_query.json")
    ap.add_argument("--report", default="reports/benchmarks.json")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="allowed slowdown factor before warning")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on warnings (off: warn-only)")
    args = ap.parse_args()

    baseline_path = pathlib.Path(args.baseline)
    report_path = pathlib.Path(args.report)
    if not baseline_path.exists():
        print(f"::warning::no baseline at {baseline_path}; skipping compare")
        return 0
    if not report_path.exists():
        print(f"::warning::no report at {report_path}; skipping compare")
        return 0
    compared, warnings = compare(
        json.loads(baseline_path.read_text()),
        json.loads(report_path.read_text()),
        args.tolerance)
    print(f"# compared {compared} rows against {baseline_path}")
    for w in warnings:
        print(f"::warning::{w}")
    if not warnings:
        print("# no regressions beyond tolerance")
    return 1 if (args.strict and warnings) else 0


if __name__ == "__main__":
    sys.exit(main())
