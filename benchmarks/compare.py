"""Benchmark regression gate: strict on counts, warn-only on timings.

Compares a fresh ``reports/benchmarks.json`` against a checked-in baseline
row-by-row (matched on ``name``).  Two classes of metric are treated
differently:

* **count metrics** (exact-evaluation fractions, backend dispatch counts —
  deterministic for fixed seeds) fail the gate when they regress and
  ``--strict-counts`` (the CI default since PR 2) or ``--strict`` is set;
* **timing metrics** (``us_per_call``) only ever emit GitHub Actions
  ``::warning::`` annotations unless full ``--strict`` is requested — CI
  runner variance makes wall-clock a trajectory signal, not a gate.

  python -m benchmarks.compare --baseline BENCH_query.json \
      --report reports/benchmarks.json [--tolerance 1.5] \
      [--strict-counts] [--strict]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

#: deterministic, seed-fixed metrics: any increase is a real regression
#: (``traces`` = kernel recompiles on a warm sweep; ``fused_pruned`` is
#: gated through ``evals_frac`` — the *unpruned* fraction — so that a
#: weaker prune certificate reads as the increase it is; the space keys
#: — ``list_entries``/``entries_per_obj``/``avg_parents``/``max_parents``/
#: ``size_mb`` — gate figs 5-7 index-overhead growth)
COUNT_KEYS = ("evals_frac", "dispatches", "build_evals", "build_dispatches",
              "lb_evals", "rounds", "traces", "list_entries",
              "entries_per_obj", "avg_parents", "max_parents", "size_mb")

#: exactness metrics (hit-set fractions from the fig-12 matching curves,
#: plus serve-engine hit totals vs the host-loop oracle): deterministic
#: for fixed seeds and gated on ANY change — a decrease is missed hits,
#: an increase is spurious hits
EXACT_KEYS = ("uniq_frac", "consec_frac", "exact_hits", "mismatches",
              "swaps")


def _rows_by_name(rows):
    return {r["name"]: r for r in rows if "name" in r}


def compare(baseline_rows, report_rows, tolerance: float):
    """Returns (n_compared, timing_warnings, count_warnings)."""
    base = _rows_by_name(baseline_rows)
    rep = _rows_by_name(report_rows)
    timing, counts = [], []
    compared = 0
    for name, b in sorted(base.items()):
        r = rep.get(name)
        if r is None:
            continue
        compared += 1
        b_us, r_us = float(b["us_per_call"]), float(r["us_per_call"])
        if b_us > 0 and r_us > tolerance * b_us:
            timing.append(
                f"{name}: {r_us:.1f}us vs baseline {b_us:.1f}us "
                f"({r_us / b_us:.2f}x, tolerance {tolerance:.2f}x)")
        for key in COUNT_KEYS:
            if key in b and key in r and float(r[key]) > float(b[key]) * 1.01:
                counts.append(
                    f"{name}: {key} rose {b[key]} -> {r[key]} "
                    "(pruning/batching regression)")
        for key in EXACT_KEYS:
            if key in b and key in r and float(r[key]) != float(b[key]):
                counts.append(
                    f"{name}: {key} changed {b[key]} -> {r[key]} "
                    "(hit-set exactness drift)")
    return compared, timing, counts


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_query.json")
    ap.add_argument("--report", default="reports/benchmarks.json")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="allowed slowdown factor before warning")
    ap.add_argument("--strict-counts", action="store_true",
                    help="exit nonzero on count-metric regressions "
                         "(deterministic; the CI gate)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on ANY warning, timing included")
    args = ap.parse_args()

    baseline_path = pathlib.Path(args.baseline)
    report_path = pathlib.Path(args.report)
    if not baseline_path.exists():
        print(f"::warning::no baseline at {baseline_path}; skipping compare")
        return 0
    if not report_path.exists():
        print(f"::warning::no report at {report_path}; skipping compare")
        return 0
    compared, timing, counts = compare(
        json.loads(baseline_path.read_text()),
        json.loads(report_path.read_text()),
        args.tolerance)
    print(f"# compared {compared} rows against {baseline_path}")
    for w in timing:
        print(f"::warning::{w}")
    for w in counts:
        print(f"::error::{w}" if (args.strict or args.strict_counts)
              else f"::warning::{w}")
    if not timing and not counts:
        print("# no regressions beyond tolerance")
    if args.strict and (timing or counts):
        return 1
    if args.strict_counts and counts:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
