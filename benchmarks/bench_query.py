"""Paper Figs. 8-11 — range-query pruning: % distance computations vs the
naive scan for RN / RN-5 / RN-tight / CT / MV-5 / MV-50 across range sizes,
on PROTEINS (Levenshtein), SONGS (DFD), TRAJ (ERP + DFD)."""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import mutate_queries, row
from repro.core.covertree import CoverTree
from repro.core.refindex import MVReferenceIndex
from repro.core.refnet import ReferenceNet
from repro.data import synthetic
from repro.distances import get


def _indices(dist_name, data, eps_prime):
    dist = get(dist_name)
    return {
        "rn": ReferenceNet(dist, data, eps_prime=eps_prime).build(),
        "rn5": ReferenceNet(dist, data, eps_prime=eps_prime,
                            num_max=5).build(),
        "rn_tight": ReferenceNet(dist, data, eps_prime=eps_prime,
                                 num_max=5, tight_bounds=True).build(),
        "ct": CoverTree(dist, data, eps_prime=eps_prime).build(),
        "mv5": MVReferenceIndex(dist, data, n_refs=5).build(),
        "mv50": MVReferenceIndex(dist, data, n_refs=50).build(),
    }


def _sweep(name, dist_name, data, eps_prime, ranges, n_queries, out):
    idx = _indices(dist_name, data, eps_prime)
    qs = mutate_queries(data, n_queries, seed=2)
    N = len(data)
    for eps in ranges:
        base = None
        for label, net in idx.items():
            net.counter.reset()
            t0 = time.perf_counter()
            hits = 0
            for q in qs:
                res = net.range_query(q, eps)
                hits += len(res)
            dt = (time.perf_counter() - t0) * 1e6 / n_queries
            frac = net.counter.count / (n_queries * N)
            if base is None:
                base = hits
            assert hits == base, f"{label} disagrees at eps={eps}"
            out.append(row(
                f"{name}_eps{eps}_{label}", dt,
                evals_frac=round(frac, 4),
                hits_per_query=round(hits / n_queries, 1),
            ))


def run(full: bool = False):
    out = []
    n = 4000 if full else 1200
    nq = 20 if full else 8
    data = synthetic.proteins(n, seed=0)
    _sweep("fig8_proteins_lev", "levenshtein", data, 1.0,
           [1.0, 2.0, 4.0, 8.0], nq, out)
    songs = synthetic.songs(n, seed=0)
    _sweep("fig9_songs_dfd", "frechet", songs, 0.5,
           [0.5, 1.0, 2.0], nq, out)
    traj = synthetic.trajectories(n, seed=0)
    _sweep("fig10_traj_erp", "erp", traj, 2.0,
           [1.0, 2.0, 4.0], nq, out)
    _sweep("fig11_traj_dfd", "frechet", traj, 0.5,
           [0.25, 0.5, 1.0], nq, out)
    return out
