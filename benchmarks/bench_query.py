"""Paper Figs. 8-11 — range-query pruning: % distance computations vs the
naive scan for RN / RN-5 / RN-tight / CT / MV-5 / MV-50 across range sizes,
on PROTEINS (Levenshtein), SONGS (DFD), TRAJ (ERP + DFD).

Since PR 4 every cell runs through the ``repro.retrieval`` facade — one
``RetrievalConfig`` per index variant, count-identical to the direct
substrate calls it replaced — and each (eps, index) cell is measured twice:

* host mode  — ``.via("host")``: the classic per-query sequential traversal
  (one backend dispatch per frontier of one query);
* engine     — ``.via("batched")``: the batched frontier engine
  (``core/batch_engine.py``) driving ALL queries' plans together, one
  ``Distance.batch`` dispatch per merged round.

Exact-evaluation counts are identical by construction (asserted); the
``dispatches`` column shows the Python-level dispatch collapse and
``speedup`` the resulting wall-clock ratio.  ``*_lb`` rows additionally
enable the lower-bound cascade (``.lb()``: pruned exact DPs; hit sets
unchanged).
"""

from __future__ import annotations

import time

from benchmarks.common import mutate_queries, row
from repro.retrieval import RetrievalConfig, Retriever


def _retrievers(dist_name, data, eps_prime):
    # bulk_build=False keeps the historical sequential-insert structure the
    # checked-in count baselines were recorded against
    base = RetrievalConfig(dist_name, eps_prime=eps_prime, bulk_build=False)
    configs = {
        "rn": base,
        "rn5": base.replace(num_max=5),
        "rn_tight": base.replace(num_max=5, tight_bounds=True),
        "ct": base.replace(index="covertree"),
        "mv5": base.replace(index="mv", mv_refs=5),
        "mv50": base.replace(index="mv", mv_refs=50),
    }
    return {k: Retriever.build(cfg, data) for k, cfg in configs.items()}


def _sweep(name, dist_name, data, eps_prime, ranges, n_queries, out,
           lb_labels=("rn_tight",)):
    idx = _retrievers(dist_name, data, eps_prime)
    qs = mutate_queries(data, n_queries, seed=2)
    N = len(data)
    for eps in ranges:
        base = None
        for label, r in idx.items():
            # host mode: per-query sequential traversal
            r.reset_counter()
            t0 = time.perf_counter()
            host = r.batch(qs).via("host").range(eps)
            host_dt = (time.perf_counter() - t0) * 1e6 / n_queries
            hits = sum(len(h) for h in host.hits)
            frac = host.stats["query"] / (n_queries * N)
            if base is None:
                base = hits
            assert hits == base, f"{label} disagrees at eps={eps}"
            out.append(row(
                f"{name}_eps{eps}_{label}", host_dt,
                evals_frac=round(frac, 4),
                hits_per_query=round(hits / n_queries, 1),
                dispatches=host.stats["dispatches"],
            ))

            # batched frontier engine: all queries, one dispatch per round
            r.reset_counter()
            t0 = time.perf_counter()
            eng = r.batch(qs).via("batched").range(eps)
            eng_dt = (time.perf_counter() - t0) * 1e6 / n_queries
            assert eng.hits == host.hits, f"{label} engine mismatch eps={eps}"
            assert eng.stats["query"] == host.stats["query"], \
                f"{label} engine eval-count drift eps={eps}"
            out.append(row(
                f"{name}_eps{eps}_{label}_engine", eng_dt,
                evals_frac=round(frac, 4),
                dispatches=eng.stats["dispatches"],
                rounds=eng.stats["rounds"],
                speedup=round(host_dt / max(eng_dt, 1e-9), 2),
            ))

            # LB cascade on top of the engine (subset: it changes counts)
            if label in lb_labels:
                r.reset_counter()
                t0 = time.perf_counter()
                lbr = r.batch(qs).via("batched").lb().range(eps)
                lb_dt = (time.perf_counter() - t0) * 1e6 / n_queries
                assert lbr.hits == host.hits, f"{label} lb mismatch eps={eps}"
                out.append(row(
                    f"{name}_eps{eps}_{label}_engine_lb", lb_dt,
                    evals_frac=round(lbr.stats["query"] / (n_queries * N), 4),
                    lb_evals=lbr.stats["lb"],
                    speedup=round(host_dt / max(lb_dt, 1e-9), 2),
                ))


def run(full: bool = False):
    from repro.data import synthetic
    out = []
    n = 4000 if full else 1200
    nq = 20 if full else 8
    data = synthetic.proteins(n, seed=0)
    _sweep("fig8_proteins_lev", "levenshtein", data, 1.0,
           [1.0, 2.0, 4.0, 8.0], nq, out)
    songs = synthetic.songs(n, seed=0)
    _sweep("fig9_songs_dfd", "frechet", songs, 0.5,
           [0.5, 1.0, 2.0], nq, out)
    traj = synthetic.trajectories(n, seed=0)
    _sweep("fig10_traj_erp", "erp", traj, 2.0,
           [1.0, 2.0, 4.0], nq, out)
    _sweep("fig11_traj_dfd", "frechet", traj, 0.5,
           [0.25, 0.5, 1.0], nq, out)
    return out
