"""Paper Figs. 8-11 — range-query pruning: % distance computations vs the
naive scan for RN / RN-5 / RN-tight / CT / MV-5 / MV-50 across range sizes,
on PROTEINS (Levenshtein), SONGS (DFD), TRAJ (ERP + DFD).

Each (eps, index) cell is measured twice:

* host mode  — the classic per-query sequential traversal (one backend
  dispatch per frontier of one query);
* engine     — the batched frontier engine (``core/batch_engine.py``)
  driving ALL queries' plans together, one ``Distance.batch`` dispatch per
  merged round.

Exact-evaluation counts are identical by construction (asserted); the
``dispatches`` column shows the Python-level dispatch collapse and
``speedup`` the resulting wall-clock ratio.  ``*_lb`` rows additionally
enable the lower-bound cascade (pruned exact DPs; hit sets unchanged).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mutate_queries, row
from repro.core.batch_engine import BatchEngine
from repro.core.covertree import CoverTree
from repro.core.refindex import MVReferenceIndex
from repro.core.refnet import ReferenceNet
from repro.data import synthetic
from repro.distances import get


def _indices(dist_name, data, eps_prime):
    dist = get(dist_name)
    return {
        "rn": ReferenceNet(dist, data, eps_prime=eps_prime).build(),
        "rn5": ReferenceNet(dist, data, eps_prime=eps_prime,
                            num_max=5).build(),
        "rn_tight": ReferenceNet(dist, data, eps_prime=eps_prime,
                                 num_max=5, tight_bounds=True).build(),
        "ct": CoverTree(dist, data, eps_prime=eps_prime).build(),
        "mv5": MVReferenceIndex(dist, data, n_refs=5).build(),
        "mv50": MVReferenceIndex(dist, data, n_refs=50).build(),
    }


def _sweep(name, dist_name, data, eps_prime, ranges, n_queries, out,
           lb_labels=("rn_tight",)):
    idx = _indices(dist_name, data, eps_prime)
    qs = mutate_queries(data, n_queries, seed=2)
    N = len(data)
    for eps in ranges:
        base = None
        for label, net in idx.items():
            # host mode: per-query sequential traversal
            net.counter.reset()
            t0 = time.perf_counter()
            host_res = [net.range_query(q, eps) for q in qs]
            host_dt = (time.perf_counter() - t0) * 1e6 / n_queries
            host_evals, host_disp = net.counter.count, net.counter.dispatches
            hits = sum(len(r) for r in host_res)
            frac = host_evals / (n_queries * N)
            if base is None:
                base = hits
            assert hits == base, f"{label} disagrees at eps={eps}"
            out.append(row(
                f"{name}_eps{eps}_{label}", host_dt,
                evals_frac=round(frac, 4),
                hits_per_query=round(hits / n_queries, 1),
                dispatches=host_disp,
            ))

            # batched frontier engine: all queries, one dispatch per round
            net.counter.reset()
            engine = BatchEngine(net.counter)
            t0 = time.perf_counter()
            eng_res = engine.run(
                [net.range_query_plan(eps) for _ in qs], qs, eps)
            eng_dt = (time.perf_counter() - t0) * 1e6 / n_queries
            assert eng_res == host_res, f"{label} engine mismatch eps={eps}"
            assert net.counter.count == host_evals, \
                f"{label} engine eval-count drift eps={eps}"
            out.append(row(
                f"{name}_eps{eps}_{label}_engine", eng_dt,
                evals_frac=round(frac, 4),
                dispatches=net.counter.dispatches,
                rounds=engine.rounds,
                speedup=round(host_dt / max(eng_dt, 1e-9), 2),
            ))

            # LB cascade on top of the engine (subset: it changes counts)
            if label in lb_labels:
                net.counter.reset()
                casc = BatchEngine(net.counter, lb_cascade=True)
                t0 = time.perf_counter()
                lb_res = casc.run(
                    [net.range_query_plan(eps) for _ in qs], qs, eps)
                lb_dt = (time.perf_counter() - t0) * 1e6 / n_queries
                assert lb_res == host_res, f"{label} lb mismatch eps={eps}"
                out.append(row(
                    f"{name}_eps{eps}_{label}_engine_lb", lb_dt,
                    evals_frac=round(net.counter.count / (n_queries * N), 4),
                    lb_evals=net.counter.lb_count,
                    speedup=round(host_dt / max(lb_dt, 1e-9), 2),
                ))


def run(full: bool = False):
    out = []
    n = 4000 if full else 1200
    nq = 20 if full else 8
    data = synthetic.proteins(n, seed=0)
    _sweep("fig8_proteins_lev", "levenshtein", data, 1.0,
           [1.0, 2.0, 4.0, 8.0], nq, out)
    songs = synthetic.songs(n, seed=0)
    _sweep("fig9_songs_dfd", "frechet", songs, 0.5,
           [0.5, 1.0, 2.0], nq, out)
    traj = synthetic.trajectories(n, seed=0)
    _sweep("fig10_traj_erp", "erp", traj, 2.0,
           [1.0, 2.0, 4.0], nq, out)
    _sweep("fig11_traj_dfd", "frechet", traj, 0.5,
           [0.25, 0.5, 1.0], nq, out)
    return out
