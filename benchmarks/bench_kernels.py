"""Device-kernel substrate benchmark — the dispatch-collapse, fused-ε and
execution-mode gates for the unified kernel registry.

Deterministic properties (count metrics, compared strict in CI against
``BENCH_kernels.json``):

* **Packed round dispatch** — at the ``bench_query`` workload size, a
  ragged query batch (segment lengths spread over ``2*lambda0 + 1``
  buckets, §5) must cost ONE backend dispatch per engine round, not one
  per round per bucket: the packed path is gated at >= 2x fewer
  dispatches than per-bucket driving (in practice ~ the bucket count).
* **Fused ε prune rate (scan backend)** — the compiled ``lax.scan``
  wavefront (``exec="scan"``) runs the packed fused-ε dispatch end to
  end: hit sets must match the numpy per-row oracle exactly, and rows
  certified ``> eps`` before their answer diagonal are flagged pruned.
  The *unpruned* fraction is the count metric (a rise means the fused
  certificate weakened).  This replaces the old interpret-mode device
  row — the scan backend is a real compiled executable on CPU CI, so the
  wall-clock next to it is meaningful, not an interpreter artifact.
* **Scan vs host loop** — the compiled scan backend must beat the numpy
  per-row host loop on wall-clock while matching its hit counts
  (asserted hard, not just recorded).
* **Per-band arithmetic intensity** — the tiled (VMEM-banded) wavefront
  schedule must report strictly higher per-band arithmetic intensity
  than the untiled schedule (``roofline.hlo_costs.band_intensity_report``
  merged into ``kernel_cost_report``): the banding is the point.
* **Trace discipline** — repeating a shape-stable sweep must compile
  nothing new (``traces`` stays 0); the registry owns one jit cache for
  every caller, and the tiled + scan variants live in the SAME cache
  (keys extended with ``(exec_mode, tile)``).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mutate_queries, row, timeit
from repro.distances import oracles
from repro.kernels import dispatch, ops, registry
from repro.retrieval import RetrievalConfig, Retriever
from repro.roofline.hlo_costs import kernel_cost_report


def run(full: bool = False):
    from repro.data import synthetic
    out = []
    n = 4000 if full else 1200
    nq = 20 if full else 8
    eps = 2.0
    data = synthetic.proteins(n, seed=0)

    # -- packed vs per-bucket dispatch at the bench_query workload size ----
    r = Retriever.build(
        RetrievalConfig("levenshtein", eps_prime=1.0, bulk_build=False),
        data)
    rng = np.random.default_rng(2)
    qs_full = mutate_queries(data, nq, seed=2)
    l = data.shape[1]
    lens = rng.integers(l - 2, l + 3, nq)   # lambda0=2-style length spread
    qs = [q[:ln] for q, ln in zip(qs_full, lens)]
    n_buckets = len(set(int(x) for x in lens))

    r.reset_counter()
    t0 = time.perf_counter()
    packed = r.batch(qs).via("batched").range(eps)
    packed_dt = (time.perf_counter() - t0) * 1e6 / nq
    packed_disp = packed.stats["dispatches"]

    r.reset_counter()
    t0 = time.perf_counter()
    bucket_disp = 0
    bucket_hits = {}
    for ln in sorted(set(int(x) for x in lens)):
        sel = [i for i in range(nq) if lens[i] == ln]
        res = r.batch([qs[i] for i in sel]).via("batched").range(eps)
        bucket_disp += res.stats["dispatches"]
        for i, h in zip(sel, res.hits):
            bucket_hits[i] = h
    bucket_dt = (time.perf_counter() - t0) * 1e6 / nq
    assert packed.hits == [bucket_hits[i] for i in range(nq)], \
        "packed dispatch changed hit sets"
    assert packed_disp * 2 <= bucket_disp, (
        f"packed path saved < 2x dispatches "
        f"({packed_disp} vs {bucket_disp} across {n_buckets} buckets)")
    out.append(row(
        "kernels_packed_round_dispatch", packed_dt,
        dispatches=packed_disp, rounds=packed.stats["rounds"],
        buckets=n_buckets,
        dispatch_collapse=round(bucket_disp / max(packed_disp, 1), 2)))
    out.append(row(
        "kernels_per_bucket_dispatch", bucket_dt, dispatches=bucket_disp))

    # -- fused-ε prune rate on the compiled scan backend -------------------
    # row-ALIGNED mutated pairs (each query edits its own candidate row, so
    # the hit/prune split is mixed rather than all-pruned)
    nd = 512 if full else 256
    ddata = data[:nd]
    rngs = np.random.default_rng(7)
    sqs = ddata.copy()
    flips = rngs.random(sqs.shape) < 0.08
    sqs[flips] = rngs.integers(0, int(data.max()) + 1, flips.sum())
    slens = rngs.integers(l - 2, l + 1, nd)
    sxs = [sqs[i][:slens[i]] for i in range(nd)]
    xs_p, lx = dispatch.pad_ragged_rows(sxs)

    def run_scan():
        return dispatch.packed_batch(
            "levenshtein", xs_p, ddata, lx, None, eps=eps, exec="scan")

    t0 = time.perf_counter()
    ko = run_scan()
    scan_cold_dt = (time.perf_counter() - t0) * 1e6 / nd

    t0 = time.perf_counter()
    host_d = np.array([oracles.levenshtein_oracle(sxs[i], ddata[i])
                       for i in range(nd)])
    host_dt = (time.perf_counter() - t0) * 1e6 / nd
    host_hits = host_d <= eps
    assert (ko.hit == host_hits).all(), \
        "scan-backend fused dispatch changed the hit set"
    assert not (ko.pruned & ko.hit).any(), \
        "fused certificate pruned a true hit"
    pruned = int(ko.pruned.sum())
    out.append(row(
        "kernels_fused_eps_scan", scan_cold_dt,
        rows=nd, hit_count=int(ko.hit.sum()), fused_pruned=pruned,
        evals_frac=round((nd - pruned) / nd, 4),
        prune_rate=round(pruned / nd, 3)))

    # -- compiled scan vs the numpy per-row host loop ----------------------
    scan_dt = timeit(run_scan) / nd      # warm: same shapes as above
    assert scan_dt < host_dt, (
        f"compiled scan backend ({scan_dt:.1f}us/row) lost to the host "
        f"per-row loop ({host_dt:.1f}us/row)")
    out.append(row(
        "kernels_scan_vs_host_loop", scan_dt,
        host_us_per_row=round(host_dt, 1),
        speedup=round(host_dt / max(scan_dt, 1e-9), 1),
        hit_count=int(ko.hit.sum())))

    # -- per-band arithmetic intensity: tiled vs untiled schedule ----------
    Bb, Lb, db, Tb = 8, 24, 2, 8
    rs = np.random.default_rng(0)
    bxs = rs.normal(size=(Bb, Lb, db)).astype(np.float32)
    bys = rs.normal(size=(Bb, Lb, db)).astype(np.float32)
    blens = np.full(Bb, Lb, np.int32)
    bepsv = np.full(Bb, 2.0, np.float32)
    wav = registry.get("dtw")

    def fn(xs_, ys_, lx_, ly_, eps_):
        return wav.device_call(xs_, ys_, lx_, ly_, eps_,
                               interpret=True, tile=Tb)

    t0 = time.perf_counter()
    rep = kernel_cost_report(fn, bxs, bys, blens, blens, bepsv,
                             band=dict(Lx=Lb, Ly=Lb, d=db, tile=Tb))
    band_dt = (time.perf_counter() - t0) * 1e6
    assert rep["tiled_band_intensity"] > rep["untiled_band_intensity"], (
        f"tiled schedule lost the per-band intensity race "
        f"({rep['tiled_band_intensity']:.3f} vs "
        f"{rep['untiled_band_intensity']:.3f})")
    out.append(row(
        "kernels_band_intensity", band_dt,
        tile=rep["tile"], bands=rep["bands"],
        tiled_intensity=round(rep["tiled_band_intensity"], 4),
        untiled_intensity=round(rep["untiled_band_intensity"], 4)))

    # -- registry trace discipline: shape-stable sweeps compile nothing ----
    sweep = [("dtw", (16, 12, 2)), ("erp", (16, 12, 2)),
             ("lev", (16, 12, None))]

    def run_sweep():
        rs = np.random.default_rng(0)
        for mode, (B, L, d) in sweep:
            if d is None:
                xs = rs.integers(0, 8, (B, L))
                ys = rs.integers(0, 8, (B, L))
            else:
                xs = rs.normal(size=(B, L, d)).astype(np.float32)
                ys = rs.normal(size=(B, L, d)).astype(np.float32)
            ops.wavefront(xs, ys, mode, interpret=True)

    run_sweep()                       # warm the cache
    t0 = time.perf_counter()
    before = registry.STATS["traces"]
    run_sweep()
    sweep_dt = (time.perf_counter() - t0) * 1e6 / len(sweep)
    retraces = registry.STATS["traces"] - before
    assert retraces == 0, f"shape-stable sweep retraced {retraces} kernels"
    out.append(row("kernels_registry_warm_sweep", sweep_dt, traces=retraces))

    # -- and the same discipline for the tiled + scan variants -------------
    sweep2 = [("dtw", (16, 12, 2), "pallas", 5),
              ("dtw", (16, 12, 2), "scan", None),
              ("erp", (16, 12, 2), "pallas", 7),
              ("erp", (16, 12, 2), "scan", None),
              ("lev", (16, 12, None), "pallas", 5),
              ("lev", (16, 12, None), "scan", None)]

    def run_sweep2():
        rs = np.random.default_rng(0)
        for mode, (B, L, d), ex, tl in sweep2:
            if d is None:
                xs = rs.integers(0, 8, (B, L))
                ys = rs.integers(0, 8, (B, L))
            else:
                xs = rs.normal(size=(B, L, d)).astype(np.float32)
                ys = rs.normal(size=(B, L, d)).astype(np.float32)
            ops.wavefront(xs, ys, mode, interpret=True, exec=ex, tile=tl)

    run_sweep2()                      # warm the tiled/scan cache entries
    t0 = time.perf_counter()
    before = registry.STATS["traces"]
    run_sweep2()
    sweep2_dt = (time.perf_counter() - t0) * 1e6 / len(sweep2)
    retraces = registry.STATS["traces"] - before
    assert retraces == 0, \
        f"tiled/scan warm sweep retraced {retraces} kernels"
    out.append(row("kernels_tiled_scan_warm_sweep", sweep2_dt,
                   traces=retraces))
    return out
