"""Device-kernel substrate benchmark — the dispatch-collapse and fused-ε
gates for the unified kernel registry.

Three deterministic properties (count metrics, compared strict in CI
against ``BENCH_kernels.json``):

* **Packed round dispatch** — at the ``bench_query`` workload size, a
  ragged query batch (segment lengths spread over ``2*lambda0 + 1``
  buckets, §5) must cost ONE backend dispatch per engine round, not one
  per round per bucket: the packed path is gated at >= 2x fewer
  dispatches than per-bucket driving (in practice ~ the bucket count).
* **Fused ε prune rate** — the device query path's survivor evaluation
  returns hit masks from the kernel; rows certified ``> eps`` on an early
  diagonal never materialize distances.  The *unpruned* fraction is the
  count metric (a rise means the fused certificate weakened).
* **Trace discipline** — repeating a shape-stable sweep must compile
  nothing new (``traces`` stays 0); the registry owns one jit cache for
  every caller.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mutate_queries, row
from repro.core.distributed import (device_range_query, flatten_net,
                                    host_reference_hits)
from repro.core.refnet import ReferenceNet
from repro.kernels import ops, registry
from repro.retrieval import RetrievalConfig, Retriever


def run(full: bool = False):
    from repro.data import synthetic
    out = []
    n = 4000 if full else 1200
    nq = 20 if full else 8
    eps = 2.0
    data = synthetic.proteins(n, seed=0)

    # -- packed vs per-bucket dispatch at the bench_query workload size ----
    r = Retriever.build(
        RetrievalConfig("levenshtein", eps_prime=1.0, bulk_build=False),
        data)
    rng = np.random.default_rng(2)
    qs_full = mutate_queries(data, nq, seed=2)
    l = data.shape[1]
    lens = rng.integers(l - 2, l + 3, nq)   # lambda0=2-style length spread
    qs = [q[:ln] for q, ln in zip(qs_full, lens)]
    n_buckets = len(set(int(x) for x in lens))

    r.reset_counter()
    t0 = time.perf_counter()
    packed = r.batch(qs).via("batched").range(eps)
    packed_dt = (time.perf_counter() - t0) * 1e6 / nq
    packed_disp = packed.stats["dispatches"]

    r.reset_counter()
    t0 = time.perf_counter()
    bucket_disp = 0
    bucket_hits = {}
    for ln in sorted(set(int(x) for x in lens)):
        sel = [i for i in range(nq) if lens[i] == ln]
        res = r.batch([qs[i] for i in sel]).via("batched").range(eps)
        bucket_disp += res.stats["dispatches"]
        for i, h in zip(sel, res.hits):
            bucket_hits[i] = h
    bucket_dt = (time.perf_counter() - t0) * 1e6 / nq
    assert packed.hits == [bucket_hits[i] for i in range(nq)], \
        "packed dispatch changed hit sets"
    assert packed_disp * 2 <= bucket_disp, (
        f"packed path saved < 2x dispatches "
        f"({packed_disp} vs {bucket_disp} across {n_buckets} buckets)")
    out.append(row(
        "kernels_packed_round_dispatch", packed_dt,
        dispatches=packed_disp, rounds=packed.stats["rounds"],
        buckets=n_buckets,
        dispatch_collapse=round(bucket_disp / max(packed_disp, 1), 2)))
    out.append(row(
        "kernels_per_bucket_dispatch", bucket_dt, dispatches=bucket_disp))

    # -- fused-ε prune rate on the device query path -----------------------
    nd = 600 if full else 240
    nqd = 4
    ddata = data[:nd]
    net = ReferenceNet("levenshtein", ddata, eps_prime=1.0,
                       tight_bounds=True).build()
    flat = flatten_net(net)
    dqs = mutate_queries(ddata, nqd, seed=5)
    t0 = time.perf_counter()
    hits, stats = device_range_query(flat, dqs, eps)
    dev_dt = (time.perf_counter() - t0) * 1e6 / nqd
    assert (hits == host_reference_hits(flat, dqs, eps)).all(), \
        "fused device query lost exactness"
    unpruned = stats["member_evals"] - stats["fused_pruned"]
    out.append(row(
        "kernels_fused_eps_device", dev_dt,
        evals_frac=round(unpruned / (nqd * nd), 4),
        member_evals=stats["member_evals"],
        fused_pruned=stats["fused_pruned"],
        prune_rate=round(stats["fused_pruned"]
                         / max(stats["member_evals"], 1), 3)))

    # -- registry trace discipline: shape-stable sweeps compile nothing ----
    sweep = [("dtw", (16, 12, 2)), ("erp", (16, 12, 2)),
             ("lev", (16, 12, None))]

    def run_sweep():
        rs = np.random.default_rng(0)
        for mode, (B, L, d) in sweep:
            if d is None:
                xs = rs.integers(0, 8, (B, L))
                ys = rs.integers(0, 8, (B, L))
            else:
                xs = rs.normal(size=(B, L, d)).astype(np.float32)
                ys = rs.normal(size=(B, L, d)).astype(np.float32)
            ops.wavefront(xs, ys, mode, interpret=True)

    run_sweep()                       # warm the cache
    t0 = time.perf_counter()
    before = registry.STATS["traces"]
    run_sweep()
    sweep_dt = (time.perf_counter() - t0) * 1e6 / len(sweep)
    retraces = registry.STATS["traces"] - before
    assert retraces == 0, f"shape-stable sweep retraced {retraces} kernels"
    out.append(row("kernels_registry_warm_sweep", sweep_dt, traces=retraces))
    return out
