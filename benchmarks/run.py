"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,...]
                                          [--hardware]

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py) and
writes reports/benchmarks.json.  ``--hardware`` appends the opt-in
real-accelerator lane (``benchmarks.bench_hardware``: compiled Pallas,
``interpret=False``) — wall-clock only, never count-gated, and it skips
itself cleanly when no accelerator backend is attached.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

MODULES = [
    ("distances", "benchmarks.bench_distances"),   # fig 4
    ("space", "benchmarks.bench_space"),           # figs 5-7
    ("build", "benchmarks.bench_build"),           # bulk construction
    ("query", "benchmarks.bench_query"),           # figs 8-11
    ("matching", "benchmarks.bench_matching"),     # fig 12 + types II/III
    ("device", "benchmarks.bench_device"),         # TPU-adapted mode
    ("elastic", "benchmarks.bench_elastic"),       # fleet serving + resize
    ("kernels", "benchmarks.bench_kernels"),       # kernel registry + packing
    ("bounds", "benchmarks.bench_bounds"),         # tiered LB cascade
    ("serve", "benchmarks.bench_serve"),           # continuous batching
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in MODULES) + ",hardware")
    ap.add_argument("--hardware", action="store_true",
                    help="append the real-accelerator lane "
                         "(compiled Pallas, interpret=False; "
                         "skips cleanly on CPU-only hosts)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    modules = list(MODULES)
    if args.hardware:
        modules.append(("hardware", "benchmarks.bench_hardware"))

    import importlib
    all_rows = []
    print("name,us_per_call,derived")
    for key, modname in modules:
        if only and key not in only:
            continue
        t0 = time.time()
        mod = importlib.import_module(modname)
        rows = mod.run(full=args.full)
        all_rows.extend({"suite": key, **r} for r in rows)
        print(f"# {key}: {len(rows)} rows in {time.time()-t0:.1f}s")
    out = pathlib.Path(__file__).resolve().parents[1] / "reports"
    out.mkdir(exist_ok=True)
    (out / "benchmarks.json").write_text(json.dumps(all_rows, indent=2))
    print(f"# wrote {out/'benchmarks.json'} ({len(all_rows)} rows)")


if __name__ == "__main__":
    main()
