"""Paper Fig. 12 + §7 — end-to-end subsequence matching: unique matching
windows vs consecutive (>=2 chained) windows as eps grows, plus type-II/III
query latency through the full 5-step pipeline — built and queried through
the ``repro.retrieval`` facade (the matcher underneath is count-identical
to the direct path)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.data import synthetic
from repro.retrieval import RetrievalConfig, Retriever


def run(full: bool = False):
    out = []
    lam, l0 = 40, 2          # l = 20, the paper's window size
    n_seqs = 40 if full else 12
    seqs = synthetic.protein_sequences(n_seqs, length=400, seed=0)
    r = Retriever.build(
        RetrievalConfig("levenshtein", lam=lam, lambda0=l0, index="refnet",
                        tight_bounds=True, num_max=5), seqs)
    m = r.matcher   # step-4 internals (segment_hits) for the fig-12 curves
    n_windows = len(r.meta)
    Q = np.concatenate([seqs[1][37:37 + 60], seqs[2][100:160]])
    for eps in [1.0, 2.0, 4.0, 8.0, 12.0]:
        r.reset_counter()
        t0 = time.perf_counter()
        hits = m.segment_hits(Q, eps)
        dt = (time.perf_counter() - t0) * 1e6
        uniq = {h.window_idx for h in hits}
        # consecutive pairs (the fig-12 "at least two consecutive" curve)
        starts = {}
        for h in hits:
            starts.setdefault(h.window.seq_id, set()).add(h.window.start)
        consec = set()
        for sid, ss in starts.items():
            for s in ss:
                if s + m.l in ss:
                    consec.add((sid, s))
                    consec.add((sid, s + m.l))
        out.append(row(
            f"fig12_matching_eps{eps}", dt,
            uniq_frac=round(len(uniq) / n_windows, 4),
            consec_frac=round(len(consec) / n_windows, 4),
            evals_frac=round(m.eval_count / (n_windows * max(
                1, sum(1 for _ in hits) or 1)), 6) if hits else 0.0,
        ))
    # type II / III end-to-end latency through the fluent plan API
    t0 = time.perf_counter()
    best = r.query(Q).longest(4.0).first
    us2 = (time.perf_counter() - t0) * 1e6
    out.append(row("type2_longest_latency", us2,
                   q_len=best.q_len if best else 0))
    t0 = time.perf_counter()
    near = r.query(Q).nearest(12.0).first
    us3 = (time.perf_counter() - t0) * 1e6
    out.append(row("type3_nearest_latency", us3,
                   distance=round(near.distance, 2) if near else -1))
    return out
