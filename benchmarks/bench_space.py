"""Paper Figs. 5-7 — reference-net space overhead: node counts, list
entries, average parents, index bytes; linear growth; the DFD-vs-ERP
distribution effect; the num_max=5 cap."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.core.refnet import ReferenceNet
from repro.data import synthetic
from repro.distances import get


def _build(dist_name, data, eps_prime, num_max=None):
    t0 = time.perf_counter()
    net = ReferenceNet(get(dist_name), data, eps_prime=eps_prime,
                       num_max=num_max).build()
    dt = time.perf_counter() - t0
    return net, dt


def run(full: bool = False):
    out = []
    sizes = [1000, 2000, 4000] if not full else [5000, 10000, 20000]
    # fig 5: PROTEINS + Levenshtein, linear space
    for n in sizes:
        data = synthetic.proteins(n, seed=0)
        net, dt = _build("levenshtein", data, 1.0)
        s = net.stats()
        out.append(row(
            f"fig5_space_proteins_{n}", dt * 1e6 / n,
            list_entries=s["n_list_entries"],
            entries_per_obj=round(s["n_list_entries"] / n, 2),
            avg_parents=round(s["avg_parents"], 2),
            size_mb=round(s["size_bytes"] / 2**20, 3),
        ))
    # fig 6: SONGS — DFD (skewed) vs ERP (spread) vs DFD num_max=5
    n = sizes[1]
    songs = synthetic.songs(n, seed=0)
    for label, dist_name, num_max in [
            ("dfd", "frechet", None), ("erp", "erp", None),
            ("dfd_cap5", "frechet", 5)]:
        eps_prime = 0.5 if dist_name == "frechet" else 2.0
        net, dt = _build(dist_name, songs, eps_prime, num_max)
        s = net.stats()
        out.append(row(
            f"fig6_space_songs_{label}_{n}", dt * 1e6 / n,
            avg_parents=round(s["avg_parents"], 2),
            max_parents=s["max_parents"],
            list_entries=s["n_list_entries"],
            size_mb=round(s["size_bytes"] / 2**20, 3),
        ))
    # fig 7: TRAJ — both distances stay small
    traj = synthetic.trajectories(n, seed=0)
    for dist_name, eps_prime in [("frechet", 0.5), ("erp", 2.0)]:
        net, dt = _build(dist_name, traj, eps_prime)
        s = net.stats()
        out.append(row(
            f"fig7_space_traj_{dist_name}_{n}", dt * 1e6 / n,
            avg_parents=round(s["avg_parents"], 2),
            size_mb=round(s["size_bytes"] / 2**20, 3),
        ))
    return out
