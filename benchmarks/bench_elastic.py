"""Elastic fleet serving benchmark — the serving-side companion to the
build suite.

The paper's scale claim (§8) only becomes an end-to-end win when the
*fleet* layer rides the batched substrate, so this suite gates:

* **Round-based serving parity/speedup** — the default fleet path
  (`mode="rounds"`: shared-frontier rounds, one merged dispatch per round)
  must return exactly the host per-shard loop's hit sets, spend the SAME
  exact evaluations (`evals_frac` parity within 1.05x — the frontier's
  pruning is preserved, unlike the one-shot stacked path's 0.9 brute-force
  fraction), and be faster than the loop (speedup > 1: dispatches scale
  with rounds, not rounds x shards x queries).  The legacy one-shot
  stacked path is timed alongside for reference, with no speedup gate.
* **Incremental resize cost** — an N->N+1 resize moves ~1/(N+1) of the
  windows (rendezvous hashing) and must re-spend at most
  ``MAX_RESIZE_BUILD_FRAC = 2/N`` of the original full-build cost in the
  counter's ``build`` bucket: the new worker bulk-builds its ~n/(N+1)
  windows, every surviving shard sheds its departed windows by Alg.-2
  deletion + zero-eval FlatNet masking instead of rebuilding.  The shrink
  back to N (survivors *gain* windows through extend_data + cohort
  bulk-load + FlatNet.append) is gated the same way, and the round-tripped
  fleet must serve the original hit sets.

Count metrics (``build_evals``, ``evals_frac``) are deterministic for the
fixed seeds and compared strict in CI; timings are warn-only as usual.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mutate_queries, row
from repro.data import synthetic
from repro.retrieval import RetrievalConfig, Retriever

#: an N->N+1 (or N+1->N) resize may re-spend at most this fraction of the
#: original full-build evaluations (acceptance bound: 2/N for N=4 shards)
N_SHARDS = 4
MAX_RESIZE_BUILD_FRAC = 2.0 / N_SHARDS


def run(full: bool = False):
    out = []
    n = 2400 if full else 900
    eps = 2.0
    data = synthetic.proteins(n, seed=0)
    workers = [f"w{i}" for i in range(N_SHARDS)]

    t0 = time.perf_counter()
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet", workers=workers,
                        tight_bounds=True), data)
    dt = time.perf_counter() - t0
    fleet = r.elastic().index
    full_build = r.eval_stats()["build"]
    out.append(row(
        f"elastic_build_{N_SHARDS}shards", dt * 1e6 / n,
        build_evals=full_build,
        build_dispatches=sum(s.net.counter.build_dispatches
                             for s in fleet.shards.values() if s),
    ))

    # -- batched fleet serving vs host loop: parity, counts, speedup -------
    qs = mutate_queries(data, 6, seed=3)
    loop_rs = r.batch(qs).via("host").range(eps)
    want = loop_rs.hits
    loop_evals = loop_rs.stats["query"]
    rounds_rs = r.batch(qs).via("fleet-rounds").range(eps)
    assert rounds_rs.hits == want, \
        "round-based fleet serving must match the host loop"
    rounds_evals = rounds_rs.stats["device_evals"]
    assert rounds_evals <= 1.05 * loop_evals, (
        f"round-based serving lost the frontier's pruning: "
        f"{rounds_evals} device evals vs {loop_evals} on the host loop")
    oneshot_rs = r.batch(qs).via("fleet-oneshot").range(eps)  # warms jit
    assert oneshot_rs.hits == want, \
        "one-shot stacked fleet serving must match the host loop"
    oneshot_evals = oneshot_rs.stats["device_evals"]

    t0 = time.perf_counter()
    r.batch(qs).via("host").range(eps)
    t_loop = (time.perf_counter() - t0) * 1e6 / len(qs)
    t0 = time.perf_counter()
    r.batch(qs).via("fleet-rounds").range(eps)
    t_rounds = (time.perf_counter() - t0) * 1e6 / len(qs)
    t0 = time.perf_counter()
    r.batch(qs).via("fleet-oneshot").range(eps)
    t_oneshot = (time.perf_counter() - t0) * 1e6 / len(qs)
    speedup = t_loop / max(t_rounds, 1e-9)
    assert speedup > 1.0, (
        f"round-based fleet serving must beat the host loop "
        f"(loop {t_loop:.0f}us vs rounds {t_rounds:.0f}us per query)")
    out.append(row(
        f"elastic_query_loop_{N_SHARDS}shards", t_loop,
        evals_frac=round(loop_evals / (len(qs) * n), 4),
        hits=sum(len(h) for h in want),
    ))
    out.append(row(
        f"elastic_query_rounds_{N_SHARDS}shards", t_rounds,
        evals_frac=round(rounds_evals / (len(qs) * n), 4),
        speedup=round(speedup, 2),
    ))
    out.append(row(
        f"elastic_query_oneshot_{N_SHARDS}shards", t_oneshot,
        evals_frac=round(oneshot_evals / (len(qs) * n), 4),
        speedup=round(t_loop / max(t_oneshot, 1e-9), 2),
    ))

    # -- resize gate: N -> N+1 (new worker builds, survivors shrink) -------
    b0 = r.eval_stats()["build"]
    t0 = time.perf_counter()
    frac_up = r.elastic().resize(workers + [f"w{N_SHARDS}"])
    dt = (time.perf_counter() - t0) * 1e6
    spent_up = r.eval_stats()["build"] - b0
    assert spent_up <= MAX_RESIZE_BUILD_FRAC * full_build, (
        f"resize {N_SHARDS}->{N_SHARDS + 1} re-spent {spent_up} evals "
        f"(> {MAX_RESIZE_BUILD_FRAC:.2f} x full build {full_build})")
    out.append(row(
        f"elastic_resize_{N_SHARDS}to{N_SHARDS + 1}", dt,
        build_evals=spent_up, moved_frac=round(frac_up, 3),
        build_frac=round(spent_up / full_build, 4),
    ))

    # -- resize gate: N+1 -> N (survivors grow through the cohort loader) --
    b0 = r.eval_stats()["build"]
    t0 = time.perf_counter()
    frac_down = r.elastic().resize(workers)
    dt = (time.perf_counter() - t0) * 1e6
    spent_down = r.eval_stats()["build"] - b0
    assert spent_down <= MAX_RESIZE_BUILD_FRAC * full_build, (
        f"resize {N_SHARDS + 1}->{N_SHARDS} re-spent {spent_down} evals "
        f"(> {MAX_RESIZE_BUILD_FRAC:.2f} x full build {full_build})")
    out.append(row(
        f"elastic_resize_{N_SHARDS + 1}to{N_SHARDS}", dt,
        build_evals=spent_down, moved_frac=round(frac_down, 3),
        build_frac=round(spent_down / full_build, 4),
    ))

    # round-tripped fleet serves the original hit sets, on both paths
    assert r.batch(qs).range(eps).hits == want, \
        "round-trip reshard lost exactness (stacked)"
    assert r.batch(qs).via("host").range(eps).hits == want, \
        "round-trip reshard lost exactness (host loop)"
    return out
