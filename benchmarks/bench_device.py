"""Device-mode (TPU-adapted) retrieval: pruning on the flattened net with
static-capacity compaction — eval counts vs naive, plus batched-query
throughput; and the elastic fleet (shards / resize / dead-shard)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mutate_queries, row
from repro.core.batch_engine import BatchEngine
from repro.core.counter import CountedDistance
from repro.core.distributed import device_range_query, flatten_net
from repro.core.refnet import ReferenceNet
from repro.data import synthetic
from repro.distances import get
from repro.retrieval import RetrievalConfig, Retriever


def run(full: bool = False):
    out = []
    n = 2000 if full else 600
    data = synthetic.proteins(n, seed=0)
    net = ReferenceNet(get("levenshtein"), data, eps_prime=1.0,
                       tight_bounds=True).build()
    flat = flatten_net(net)
    qs = mutate_queries(data, 8, seed=4)
    for eps in [1.0, 2.0, 4.0]:
        t0 = time.perf_counter()
        hits, stats = device_range_query(flat, qs, eps)
        dt = (time.perf_counter() - t0) * 1e6 / len(qs)
        out.append(row(
            f"device_query_eps{eps}", dt,
            evals_frac=round(stats["total_evals"] / (len(qs) * n), 4),
            pivots=flat.n_pivots,
            hits=int(hits.sum()),
        ))
    # batched frontier engine over the same net, jax-backend dispatches:
    # plans are structure-only, so the host-built net drives the jitted
    # Distance.batch wavefront with one dispatch per merged round
    jcounter = CountedDistance(get("levenshtein"), data, backend="jax")
    for eps in [1.0, 2.0, 4.0]:
        jcounter.reset()
        engine = BatchEngine(jcounter)
        engine.run([net.range_query_plan(eps) for _ in qs], qs, eps)  # warm
        jcounter.reset()
        engine = BatchEngine(jcounter)
        t0 = time.perf_counter()
        engine.run([net.range_query_plan(eps) for _ in qs], qs, eps)
        dt = (time.perf_counter() - t0) * 1e6 / len(qs)
        out.append(row(
            f"engine_jax_eps{eps}", dt,
            evals_frac=round(jcounter.count / (len(qs) * n), 4),
            dispatches=jcounter.dispatches,
            rounds=engine.rounds,
        ))

    # fleet: shards + resize through the facade (the dedicated elastic
    # suite gates the counts; these rows track the device-suite view)
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet", workers=4,
                        tight_bounds=True), data)
    t0 = time.perf_counter()
    loop = r.batch(qs).via("host").range(2.0)
    dt = (time.perf_counter() - t0) * 1e6 / len(qs)
    out.append(row("fleet_query_4shards", dt,
                   evals=loop.stats["query"]))
    r.batch(qs).range(2.0)  # warm the round-based (default) serving path
    t0 = time.perf_counter()
    rounds = r.batch(qs).range(2.0)
    dt = (time.perf_counter() - t0) * 1e6 / len(qs)
    out.append(row("fleet_query_4shards_rounds", dt,
                   device_evals=rounds.stats["device_evals"]))
    build_before = r.eval_stats()["build"]
    t0 = time.perf_counter()
    frac = r.elastic().resize([f"w{i}" for i in range(5)])
    dt = (time.perf_counter() - t0) * 1e6
    out.append(row("fleet_resize_4to5", dt, moved_frac=round(frac, 3),
                   build_evals=r.eval_stats()["build"] - build_before))
    return out
