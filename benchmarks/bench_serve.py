"""Continuous-batching serve benchmark — the PR-9 serving-layer gate.

Three properties of the serve engine are asserted here and gated
count-strict in CI (``BENCH_serve.json``):

* **Shared rounds are real** — under open-loop Poisson load at a fixed
  arrival rate, the continuous-batching engine's mean merged-dispatch
  count per query (total merged rounds / requests served) is STRICTLY
  below the one-query-at-a-time loop's: overlapping requests ride the
  same packed dispatches instead of paying their own round sequence.
  ``dispatches`` gates both sides.
* **Exactness under load** — every request's hit set is identical to the
  sequential host-loop oracle (``exact_hits`` gates the total; any drift
  — missed or spurious — fails the compare).
* **Zero-downtime resize** — a mid-load ``resize()`` through the
  snapshot-swap path (snapshot -> restore clone -> reshard clone -> swap
  at a round boundary) completes with ZERO failed/mismatched requests,
  asserted per request against the same oracle.

Determinism: arrivals come from a seeded Poisson schedule and serving
runs on the virtual clock (``run_schedule``, fixed ``round_cost``), so
admission patterns, dispatch counts, hit totals — and even the latency
percentiles in virtual-time units — are identical every run.  The
percentile columns (p50/p95/p99) and ``us_per_call`` are reported for
trajectory only (warn-only, like all timings); the gates are the count
and exactness keys.
"""

from __future__ import annotations

import pathlib
import tempfile
import time

import numpy as np

from benchmarks.common import mutate_queries, row
from repro.data import synthetic
from repro.retrieval import RetrievalConfig, Retriever
from repro.serve import FleetSnapshotManager, ServeConfig, ServeEngine, \
    poisson_schedule

N_SHARDS = 4
EPS = 2.0
QPS = 1.0          # arrivals per round_cost unit: ~frontier-depth overlap
N_QUERIES = 16


def _build(data, workers):
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet", workers=workers,
                        tight_bounds=True), data)
    return r, r.elastic().index


def run(full: bool = False):
    out = []
    n = 2400 if full else 600
    data = synthetic.proteins(n, seed=0)
    workers = [f"w{i}" for i in range(N_SHARDS)]
    r, fleet = _build(data, workers)
    qs = mutate_queries(data, N_QUERIES, seed=3)
    arrivals = poisson_schedule(QPS, N_QUERIES / QPS, seed=7)[:N_QUERIES]
    while len(arrivals) < N_QUERIES:   # top up a short pathological draw
        arrivals = np.concatenate([arrivals, arrivals[-1:] + 1.0])

    # sequential host-loop oracle (ONE facade call; the exactness anchor)
    oracle = r.batch(qs).via("host").range(EPS).hits
    total_hits = sum(len(h) for h in oracle)

    # -- one-query-at-a-time baseline: fresh rounds-mode run per query ----
    # (each query pays its whole round sequence alone: dispatches/query =
    # its frontier depth)
    r0 = fleet.device_stats["rounds"]
    t0 = time.perf_counter()
    seq_hits = [fleet.range_query_batch([q], EPS)[0] for q in qs]
    t_seq = (time.perf_counter() - t0) * 1e6 / N_QUERIES
    seq_rounds = fleet.device_stats["rounds"] - r0
    assert seq_hits == oracle, "sequential rounds serving drifted"
    out.append(row(
        f"serve_sequential_{N_SHARDS}shards", t_seq,
        dispatches=seq_rounds,
        per_query=round(seq_rounds / N_QUERIES, 3),
        exact_hits=total_hits,
    ))

    # -- continuous batching under Poisson load (virtual clock) -----------
    eng = ServeEngine(fleet, ServeConfig(eps=EPS, max_inflight=32))
    t0 = time.perf_counter()
    reqs = eng.run_schedule(qs, arrivals)
    t_cont = (time.perf_counter() - t0) * 1e6 / N_QUERIES
    assert [rq.hits for rq in reqs] == oracle, \
        "continuous batching drifted from the sequential oracle"
    cont_rounds = eng.engine_stats()["rounds"]
    assert cont_rounds / N_QUERIES < seq_rounds / N_QUERIES, (
        f"shared rounds are not real: continuous spent {cont_rounds} "
        f"merged dispatches for {N_QUERIES} queries vs {seq_rounds} "
        "sequentially")
    lat = eng.latency_stats()
    out.append(row(
        f"serve_continuous_{N_SHARDS}shards", t_cont,
        dispatches=cont_rounds,
        per_query=round(cont_rounds / N_QUERIES, 3),
        rounds=sum(rq.rounds for rq in reqs),
        exact_hits=sum(len(rq.hits) for rq in reqs),
        p50=round(lat["p50"], 3), p95=round(lat["p95"], 3),
        p99=round(lat["p99"], 3),
    ))

    # -- greedy admission: newcomers get a dedicated first round ----------
    _, fleet_g = _build(data, workers)
    eng_g = ServeEngine(fleet_g, ServeConfig(eps=EPS, max_inflight=32,
                                             admission="greedy"))
    t0 = time.perf_counter()
    reqs_g = eng_g.run_schedule(qs, arrivals)
    t_greedy = (time.perf_counter() - t0) * 1e6 / N_QUERIES
    assert [rq.hits for rq in reqs_g] == oracle, "greedy admission drifted"
    greedy_rounds = eng_g.engine_stats()["rounds"]
    assert greedy_rounds >= cont_rounds, \
        "greedy admission cannot spend fewer rounds than tick"
    lat_g = eng_g.latency_stats()
    out.append(row(
        f"serve_greedy_{N_SHARDS}shards", t_greedy,
        dispatches=greedy_rounds,
        exact_hits=sum(len(rq.hits) for rq in reqs_g),
        p50=round(lat_g["p50"], 3), p99=round(lat_g["p99"], 3),
    ))

    # -- snapshot round trip: atomic save + zero-eval restore -------------
    with tempfile.TemporaryDirectory() as d:
        snap = FleetSnapshotManager(d)
        t0 = time.perf_counter()
        step = snap.save(fleet, block=True)
        clone = snap.restore(step)
        t_snap = (time.perf_counter() - t0) * 1e6
        size_mb = sum(f.stat().st_size for f in
                      pathlib.Path(d).rglob("*") if f.is_file()) / 2**20
    assert clone.eval_count() == fleet.eval_count(), \
        "snapshot restore must not spend evaluations"
    assert clone.range_query_batch(list(qs), EPS) == oracle, \
        "restored fleet drifted from the oracle"
    out.append(row(
        f"serve_snapshot_{N_SHARDS}shards", t_snap,
        size_mb=round(size_mb, 2),
        exact_hits=total_hits,
    ))

    # -- zero-downtime mid-load resize through the snapshot swap ----------
    _, fleet_s = _build(data, workers)
    with tempfile.TemporaryDirectory() as d:
        eng_s = ServeEngine(fleet_s, ServeConfig(eps=EPS, max_inflight=32,
                                                 snapshot_dir=d))
        t0 = time.perf_counter()
        reqs_s = eng_s.run_schedule(
            qs, arrivals, resize_at=float(arrivals[N_QUERIES // 2]),
            resize_to=workers + [f"w{N_SHARDS}"])
        t_swap = (time.perf_counter() - t0) * 1e6 / N_QUERIES
    failed = [i for i, rq in enumerate(reqs_s) if not rq.done]
    mismatched = [i for i, rq in enumerate(reqs_s) if rq.hits != oracle[i]]
    assert not failed and not mismatched, (
        f"mid-load snapshot-swap resize broke serving: "
        f"failed={failed} mismatched={mismatched}")
    assert eng_s.swaps == 1, "the resize never swapped in"
    assert len(eng_s.fleet.workers) == N_SHARDS + 1
    out.append(row(
        f"serve_swap_{N_SHARDS}to{N_SHARDS + 1}", t_swap,
        dispatches=eng_s.engine_stats()["rounds"],
        exact_hits=sum(len(rq.hits) for rq in reqs_s),
        mismatches=len(failed) + len(mismatched),
        swaps=eng_s.swaps,
    ))
    return out
