"""Bulk-construction benchmark — the build-side companion to figs. 8-11.

On the paper's sweep datasets, build time dominates end-to-end cost once
queries are batched; this suite measures construction in the same currency
as the query benchmarks: exact evaluations and backend dispatches, both
read from the counter's ``build`` bucket.

For each index the reference workload (n >= 1000 windows) is built twice:

* ``seq``  — the classic loader: one sequential insert-plan drive per
  object (dispatch counts identical to the historical pair-at-a-time
  descent);
* ``bulk`` — ``build_batched``: cohorts of concurrent insert plans through
  the frontier engine, one merged dispatch per descent level per cohort
  plus one arbitration dispatch per cohort.

Hit-set parity between the two nets is asserted, and the bulk loader must
collapse dispatches by >= 5x (the PR-2 acceptance bound).  ``mv`` rows
time the stacked profile/table construction, ``flatten`` rows the batched
net flattening for the device path.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.core.covertree import CoverTree
from repro.core.distributed import flatten_net
from repro.core.refindex import MVReferenceIndex
from repro.core.refnet import ReferenceNet
from repro.data import synthetic
from repro.distances import get

#: the acceptance bound on the bulk loader's dispatch collapse
MIN_DISPATCH_DROP = 5.0


def _build_pair(name, cls, dist_name, data, eps_prime, out, **kw):
    dist = get(dist_name)
    n = len(data)

    t0 = time.perf_counter()
    seq = cls(dist, data, eps_prime=eps_prime, **kw).build()
    seq_dt = time.perf_counter() - t0
    seq_evals = seq.counter.build_count
    seq_disp = seq.counter.build_dispatches
    out.append(row(
        f"build_{name}_seq", seq_dt * 1e6 / n,
        build_evals=seq_evals, build_dispatches=seq_disp,
    ))

    t0 = time.perf_counter()
    bulk = cls(dist, data, eps_prime=eps_prime, **kw).build_batched()
    bulk_dt = time.perf_counter() - t0
    bulk_evals = bulk.counter.build_count
    bulk_disp = bulk.counter.build_dispatches
    drop = seq_disp / max(bulk_disp, 1)
    assert drop >= MIN_DISPATCH_DROP, \
        f"{name}: dispatch drop {drop:.1f}x < {MIN_DISPATCH_DROP}x"
    for qi in (3, n // 2):
        q = data[qi]
        assert bulk.range_query(q, 2 * eps_prime) == \
            seq.range_query(q, 2 * eps_prime), f"{name} parity at {qi}"
    out.append(row(
        f"build_{name}_bulk", bulk_dt * 1e6 / n,
        build_evals=bulk_evals, build_dispatches=bulk_disp,
        dispatch_drop=round(drop, 1),
        speedup=round(seq_dt / max(bulk_dt, 1e-9), 2),
    ))
    return bulk


def run(full: bool = False):
    out = []
    n = 4000 if full else 1200
    data = synthetic.proteins(n, seed=0)

    net = _build_pair("refnet_proteins", ReferenceNet, "levenshtein",
                      data, 1.0, out)
    _build_pair("refnet5_proteins", ReferenceNet, "levenshtein",
                data, 1.0, out, num_max=5, tight_bounds=True)
    _build_pair("covertree_proteins", CoverTree, "levenshtein",
                data, 1.0, out)

    traj = synthetic.trajectories(n // 2, seed=0)
    _build_pair("refnet_traj_erp", ReferenceNet, "erp", traj, 2.0, out)

    # MV: stacked profile/table dispatches
    t0 = time.perf_counter()
    mv = MVReferenceIndex(get("levenshtein"), data, n_refs=5).build()
    dt = time.perf_counter() - t0
    out.append(row(
        "build_mv5_proteins", dt * 1e6 / n,
        build_evals=mv.counter.build_count,
        build_dispatches=mv.counter.build_dispatches,
    ))

    # device flatten of the bulk-built net (batched, link-dist reuse)
    before_e = net.counter.build_count
    before_d = net.counter.build_dispatches
    t0 = time.perf_counter()
    flat = flatten_net(net)
    dt = time.perf_counter() - t0
    out.append(row(
        "build_flatten_proteins", dt * 1e6 / n,
        build_evals=net.counter.build_count - before_e,
        build_dispatches=net.counter.build_dispatches - before_d,
        pivots=flat.n_pivots,
    ))
    return out
