"""Paper Fig. 4 — distance distributions per (dataset x distance), plus
throughput of the batched distance backends (numpy wavefront vs JAX engine
vs Pallas interpret kernel)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.data import synthetic
from repro.distances import np_backend

CASES = [
    ("proteins", "levenshtein"),
    ("songs", "frechet"),
    ("songs", "erp"),
    ("traj", "frechet"),
    ("traj", "erp"),
]


def run(full: bool = False):
    out = []
    n = 2000 if full else 400
    for ds, dist in CASES:
        gen, _ = synthetic.DATASETS[ds]
        data = gen(n, seed=0)
        rng = np.random.default_rng(1)
        a = data[rng.integers(0, n, 512)]
        b = data[rng.integers(0, n, 512)]
        batch = np_backend.batch_for(dist)
        us = timeit(lambda: np.asarray(batch(a, b)))
        d = np.asarray(batch(a, b))
        hist, edges = np.histogram(d, bins=10)
        out.append(row(
            f"fig4_dist_{ds}_{dist}", us / 512,
            mean=round(float(d.mean()), 2),
            p10=round(float(np.percentile(d, 10)), 2),
            p90=round(float(np.percentile(d, 90)), 2),
            max=round(float(d.max()), 2),
            skew_mass_2_5=round(float(np.mean((d >= 2) & (d <= 5))), 3),
        ))
    # backend throughput on the paper's l=20 windows
    data = synthetic.proteins(1024, seed=0)
    a, b = data[:512], data[512:1024]
    us_np = timeit(lambda: np_backend.batch_alignment(a, b, "lev"))
    out.append(row("backend_numpy_wavefront_lev_l20", us_np / 512))
    from repro.distances import get
    jb = get("levenshtein").batch
    us_jax = timeit(lambda: np.asarray(jb(a, b)))
    out.append(row("backend_jax_wavefront_lev_l20", us_jax / 512))
    from repro.kernels import ops
    us_k = timeit(lambda: np.asarray(
        ops.wavefront(a[:64], b[:64], "lev", interpret=True)))
    out.append(row("backend_pallas_interpret_lev_l20", us_k / 64))
    return out
