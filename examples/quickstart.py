"""Quickstart: the paper's 5-step subsequence matching framework end-to-end,
through the unified `repro.retrieval` facade.

  PYTHONPATH=src python examples/quickstart.py

One declarative config selects distance, index, and execution; the fluent
query-plan API answers all three paper query types (§3.2).
"""

import numpy as np

from repro.data.synthetic import protein_sequences
from repro.retrieval import RetrievalConfig, Retriever

LAM, LAMBDA0, EPS = 16, 1, 2.0


def main():
    rng = np.random.default_rng(0)
    seqs = protein_sequences(8, length=300, seed=1)

    # a query containing a mutated copy of part of database sequence 3
    Q = rng.integers(0, 20, size=(80,)).astype(np.int32)
    Q[20:60] = seqs[3][100:140]
    Q[31] = (Q[31] + 1) % 20
    Q[48] = (Q[48] + 7) % 20

    config = RetrievalConfig("levenshtein", lam=LAM, lambda0=LAMBDA0,
                             index="refnet", tight_bounds=True, num_max=5)
    r = Retriever.build(config, seqs)
    print(f"indexed {len(r.meta)} windows of length {r.matcher.l} "
          f"from {len(seqs)} sequences")

    rs = r.query(Q).range(EPS)
    print(f"\n[type I] range query eps={EPS}: {len(rs)} similar pairs "
          f"({rs.stats['query']} distance evals, "
          f"{rs.stats['dispatches']} dispatches)")
    for p in rs.hits[:5]:
        print(f"  seq {p.seq_id} [{p.x_start}:{p.x_start+p.x_len}] ~ "
              f"Q[{p.q_start}:{p.q_start+p.q_len}] d={p.distance:.0f}")

    best = r.query(Q).longest(EPS).first
    print(f"\n[type II] longest similar subsequence: "
          f"seq {best.seq_id} [{best.x_start}:{best.x_start+best.x_len}] ~ "
          f"Q[{best.q_start}:{best.q_start+best.q_len}] "
          f"(|SQ|={best.q_len}, d={best.distance:.0f})")
    assert best.q_len >= 30, "planted 40-token match should dominate"

    near = r.query(Q).nearest(10.0).first
    print(f"\n[type III] nearest pair: d={near.distance:.0f} at "
          f"seq {near.seq_id} [{near.x_start}:{near.x_start+near.x_len}]")


if __name__ == "__main__":
    main()
