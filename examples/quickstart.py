"""Quickstart: the paper's 5-step subsequence matching framework end-to-end.

  PYTHONPATH=src python examples/quickstart.py

Builds a reference-net-indexed matcher over synthetic protein sequences,
plants a mutated fragment into a query, and runs all three query types.
"""

import numpy as np

from repro.core.matching import SubsequenceMatcher
from repro.data.synthetic import protein_sequences

LAM, LAMBDA0, EPS = 16, 1, 2.0


def main():
    rng = np.random.default_rng(0)
    seqs = protein_sequences(8, length=300, seed=1)

    # a query containing a mutated copy of part of database sequence 3
    Q = rng.integers(0, 20, size=(80,)).astype(np.int32)
    Q[20:60] = seqs[3][100:140]
    Q[31] = (Q[31] + 1) % 20
    Q[48] = (Q[48] + 7) % 20

    m = SubsequenceMatcher("levenshtein", LAM, LAMBDA0, index="refnet",
                           tight_bounds=True, num_max=5).build(seqs)
    print(f"indexed {len(m.meta)} windows of length {m.l} "
          f"from {len(seqs)} sequences")

    m.reset_counter()
    pairs = m.query_range(Q, EPS)
    print(f"\n[type I] range query eps={EPS}: {len(pairs)} similar pairs "
          f"({m.eval_count} distance evals)")
    for p in pairs[:5]:
        print(f"  seq {p.seq_id} [{p.x_start}:{p.x_start+p.x_len}] ~ "
              f"Q[{p.q_start}:{p.q_start+p.q_len}] d={p.distance:.0f}")

    best = m.query_longest(Q, EPS)
    print(f"\n[type II] longest similar subsequence: "
          f"seq {best.seq_id} [{best.x_start}:{best.x_start+best.x_len}] ~ "
          f"Q[{best.q_start}:{best.q_start+best.q_len}] "
          f"(|SQ|={best.q_len}, d={best.distance:.0f})")
    assert best.q_len >= 30, "planted 40-token match should dominate"

    near = m.query_nearest(Q, eps_max=10.0)
    print(f"\n[type III] nearest pair: d={near.distance:.0f} at "
          f"seq {near.seq_id} [{near.x_start}:{near.x_start+near.x_len}]")


if __name__ == "__main__":
    main()
