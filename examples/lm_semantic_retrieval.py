"""End-to-end driver (model half x paper half): train a small LM for a few
hundred steps, then use its hidden states for semantic subsequence
retrieval — embedding windows indexed in a reference net (Euclidean is
metric + consistent, paper §4).

  PYTHONPATH=src python examples/lm_semantic_retrieval.py [--steps 200]
"""

import argparse

import numpy as np

from repro.core.embedding_retrieval import embed_windows
from repro.data.pipeline import TokenBatcher
from repro.data.synthetic import token_corpus
from repro.models import registry
from repro.retrieval import RetrievalConfig, Retriever
from repro.train import optimizer as opt_lib
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    cfg, mod = registry.get(args.arch, reduced=True)
    corpus = token_corpus(256, 256, cfg.vocab, seed=0)
    batcher = TokenBatcher(corpus, batch=8, seq=64, seed=1)
    ocfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=10,
                             total_steps=args.steps)
    trainer = Trainer(mod, cfg, ocfg, batcher, "/tmp/repro_lm_ckpt",
                      TrainerConfig(total_steps=args.steps, ckpt_every=100,
                                    log_every=max(args.steps // 10, 1)))
    out = trainer.run()
    losses = [e["loss"] for e in out["log"]]
    print(f"trained {out['final_step']} steps; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training should reduce loss"

    # index hidden-state windows of a corpus slice; probe with a paraphrase
    # (here: the same sequence, which must retrieve itself at distance ~0,
    # and near-duplicates at small distance)
    rng = np.random.default_rng(5)
    seqs = [corpus[i, :96] for i in range(12)]
    dup = seqs[3].copy()
    flips = rng.random(dup.shape) < 0.05
    dup[flips] = rng.integers(0, cfg.vocab, flips.sum())
    seqs.append(dup)

    vecs, meta = embed_windows(mod, out["params"], cfg, seqs, window=16)
    ret = Retriever.build(
        RetrievalConfig("euclidean", index="embedding", eps_prime=0.02,
                        num_max=5, tight_bounds=True), vecs)
    probe = next(i for i, m in enumerate(meta) if m.seq_id == len(seqs) - 1)
    near = ret.query(vecs[probe]).nearest(2.0, tol=1e-3)
    assert near, "the probe must retrieve something"
    win, d = meta[near.first], near.distances[0]
    print(f"near-duplicate window retrieved: seq {win.seq_id} "
          f"@{win.start} (d={d:.4f}) for probe from seq {len(seqs)-1}")
    others = ret.query(vecs[probe]).range(0.5)
    print(f"{len(others)} windows within eps=0.5; "
          f"evals={ret.eval_stats()['query']} vs naive={len(vecs)}")


if __name__ == "__main__":
    main()
