"""String-database scenario (paper §8 PROTEINS): compares the reference net
against the cover tree and MV reference indexing at equal space, reporting
exact distance-evaluation counts.

  PYTHONPATH=src python examples/protein_search.py
"""

import numpy as np

from repro.core.counter import CountedDistance
from repro.core.covertree import CoverTree
from repro.core.refindex import MVReferenceIndex
from repro.core.refnet import ReferenceNet
from repro.data.synthetic import proteins
from repro.distances import get


def main():
    data = proteins(2000, seed=0)
    dist = get("levenshtein")
    rng = np.random.default_rng(1)

    indices = {
        "reference net": ReferenceNet(dist, data, eps_prime=1.0,
                                      num_max=5).build(),
        "reference net (tight)": ReferenceNet(
            dist, data, eps_prime=1.0, num_max=5, tight_bounds=True).build(),
        "cover tree": CoverTree(dist, data, eps_prime=1.0).build(),
        "MV-5 references": MVReferenceIndex(dist, data, n_refs=5).build(),
    }
    naive = CountedDistance(dist, data)

    queries = data[rng.integers(0, len(data), 10)].copy()
    flips = rng.random(queries.shape) < 0.1
    queries[flips] = rng.integers(0, 20, flips.sum())

    print(f"{'index':24s} {'eps':>4} {'evals%':>8} {'hits':>6}")
    for eps in [2.0, 4.0]:
        gold = None
        for name, net in indices.items():
            net.counter.reset()
            hits = sum(len(net.range_query(q, eps)) for q in queries)
            frac = net.counter.count / (len(queries) * len(data))
            if gold is None:
                gold = hits
            assert hits == gold, f"{name} returned different results!"
            print(f"{name:24s} {eps:4.0f} {frac:8.1%} {hits:6d}")
    print("\nall indices return identical result sets; "
          "the reference net needs the fewest distance computations")


if __name__ == "__main__":
    main()
