"""String-database scenario (paper §8 PROTEINS): compares the reference net
against the cover tree and MV reference indexing at equal space, reporting
exact distance-evaluation counts — every index behind the SAME facade
config, only the ``index`` field (and its tuning) changes.

  PYTHONPATH=src python examples/protein_search.py
"""

import numpy as np

from repro.data.synthetic import proteins
from repro.retrieval import RetrievalConfig, Retriever


def main():
    data = proteins(2000, seed=0)
    rng = np.random.default_rng(1)

    # defaults: cohort bulk construction + the batched frontier engine —
    # exact-eval fractions are engine-independent (host parity is
    # property-tested), so the comparison currency is unchanged
    base = RetrievalConfig("levenshtein", eps_prime=1.0, num_max=5)
    configs = {
        "reference net": base,
        "reference net (tight)": base.replace(tight_bounds=True),
        "cover tree": base.replace(index="covertree"),
        "MV-5 references": base.replace(index="mv", mv_refs=5),
    }
    retrievers = {name: Retriever.build(cfg, data)
                  for name, cfg in configs.items()}

    queries = data[rng.integers(0, len(data), 10)].copy()
    flips = rng.random(queries.shape) < 0.1
    queries[flips] = rng.integers(0, 20, flips.sum())

    print(f"{'index':24s} {'eps':>4} {'evals%':>8} {'hits':>6}")
    for eps in [2.0, 4.0]:
        gold = None
        for name, r in retrievers.items():
            rs = r.batch(queries).range(eps)
            hits = sum(len(h) for h in rs.hits)
            frac = rs.stats["query"] / (len(queries) * len(data))
            if gold is None:
                gold = hits
            assert hits == gold, f"{name} returned different results!"
            print(f"{name:24s} {eps:4.0f} {frac:8.1%} {hits:6d}")
    print("\nall indices return identical result sets; "
          "the reference net needs the fewest distance computations")


if __name__ == "__main__":
    main()
