"""Time-series scenario (paper §8 TRAJ): sub-trajectory retrieval under the
discrete Frechet distance and ERP — including DTW via the consistency-only
path (linear-scan filter, since DTW is not a metric; paper §5).  The facade
config validates the distance/index pairing at construction: asking for
DTW on a metric index raises before any work is done.

  PYTHONPATH=src python examples/trajectory_search.py
"""

import numpy as np

from repro.data.synthetic import trajectories
from repro.retrieval import RetrievalConfig, Retriever


def main():
    rng = np.random.default_rng(0)
    # database trajectories: 2-D tracks, 120 points each
    base = trajectories(6, l=120, seed=3)
    seqs = [t for t in base]

    # query: a noisy replay of part of trajectory 2
    Q = seqs[2][30:90] + rng.normal(scale=0.05, size=(60, 2))

    # DTW is consistent but not metric: the config layer rejects the
    # indexed path and accepts the linear-scan filter (paper §5)
    try:
        RetrievalConfig("dtw", lam=16, index="refnet")
    except ValueError as e:
        print(f"config validation: {e}\n")

    for dist_name, eps, index in [("frechet", 0.4, "refnet"),
                                  ("erp", 3.0, "refnet"),
                                  ("dtw", 2.0, "linear")]:
        cfg = RetrievalConfig(dist_name, lam=16, lambda0=1, index=index,
                              tight_bounds=(index == "refnet"))
        r = Retriever.build(cfg, seqs)
        rs = r.query(Q).longest(eps)
        best = rs.first
        n_windows = len(r.meta)
        note = "(metric index)" if index == "refnet" else \
            "(consistent but non-metric -> linear-scan filter)"
        if best is None:
            print(f"{dist_name:8s} eps={eps}: no match {note}")
            continue
        print(f"{dist_name:8s} eps={eps}: traj {best.seq_id} "
              f"[{best.x_start}:{best.x_start+best.x_len}] ~ "
              f"Q[{best.q_start}:{best.q_start+best.q_len}] "
              f"d={best.distance:.2f}  evals={rs.stats['query']} "
              f"/ naive~{n_windows * 3 * len(Q)} {note}")
        assert best.seq_id == 2, "should recover the replayed trajectory"


if __name__ == "__main__":
    main()
