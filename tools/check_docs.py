"""Docs gate: markdown link check + README quickstart smoke (stdlib only).

Run from anywhere::

    PYTHONPATH=src python tools/check_docs.py

Two checks, both CI-blocking (`.github/workflows/ci.yml`, docs job):

1. **Link check** — every relative markdown link target in the checked
   documents must exist on disk.  External (``http(s)``/``mailto``)
   links, pure in-page anchors (``#...``), and targets that escape the
   repo root (the CI badge's ``../../actions/...``) are skipped; a
   ``path#anchor`` target is checked for the path part only.
2. **Quickstart smoke** — the FIRST fenced ``python`` block of README.md
   is the facade quickstart and must stay self-contained: it is executed
   here, so the documented entry point can't silently rot.  Later blocks
   are illustrative sketches and are not run.
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

#: the documents under the link gate (repo-relative)
DOCS = (
    "README.md",
    "docs/architecture.md",
    "benchmarks/README.md",
)

#: inline markdown links: [text](target) — images included via the [!...
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def check_links(doc: pathlib.Path) -> list:
    errors = []
    # fenced code blocks routinely contain f(x)[i](j)-shaped false
    # positives, so strip them before scanning for links
    text = re.sub(r"```.*?```", "", doc.read_text(), flags=re.DOTALL)
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = (doc.parent / target.split("#", 1)[0]).resolve()
        try:
            path.relative_to(REPO)
        except ValueError:
            continue                      # escapes the repo (CI badge)
        if not path.exists():
            errors.append(f"{doc.relative_to(REPO)}: broken link -> {target}")
    return errors


def run_quickstart(readme: pathlib.Path) -> list:
    blocks = _FENCE.findall(readme.read_text())
    if not blocks:
        return [f"{readme.name}: no ```python quickstart block found"]
    src = blocks[0]
    try:
        exec(compile(src, f"{readme.name}:quickstart", "exec"), {})
    except Exception as e:  # noqa: BLE001 — any failure fails the gate
        return [f"{readme.name}: quickstart raised {type(e).__name__}: {e}"]
    return []


def main() -> int:
    errors = []
    for rel in DOCS:
        doc = REPO / rel
        if not doc.exists():
            errors.append(f"missing document: {rel}")
            continue
        errors.extend(check_links(doc))
    print(f"# link-checked {len(DOCS)} documents")
    errors.extend(run_quickstart(REPO / "README.md"))
    if errors:
        for e in errors:
            print(f"::error::{e}")
        return 1
    print("# docs OK: links resolve, quickstart runs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
