#!/usr/bin/env python
"""Substrate invariant linter CLI (the blocking CI ``lint`` job).

  PYTHONPATH=src python tools/lint.py                  # human output
  PYTHONPATH=src python tools/lint.py --format=json    # CI artifact
  PYTHONPATH=src python tools/lint.py --select dispatch,trace

Exits 0 iff the tree is clean (no findings).  ``--max-pragmas`` bounds the
number of allowlist pragma comments in use (the acceptance budget: a tree
that needs many exemptions needs fixes, not pragmas).
"""

from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro import analysis  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default="src/repro",
                    help="tree to lint (default: src/repro)")
    ap.add_argument("--format", choices=("human", "json"), default="human")
    ap.add_argument("--select", default=None,
                    help="comma-separated subset of passes "
                         f"(default: all = {','.join(analysis.pass_names())})")
    ap.add_argument("--max-pragmas", type=int, default=10,
                    help="max allowlist pragma comments in use (default 10)")
    args = ap.parse_args()

    root = pathlib.Path(args.root)
    if not root.exists():
        print(f"error: no such lint root {root}", file=sys.stderr)
        return 2
    select = args.select.split(",") if args.select else None
    findings, stats = analysis.run(root, select=select)

    over_budget = stats["pragmas_used"] > args.max_pragmas
    if args.format == "json":
        print(analysis.to_json(findings, stats))
    else:
        print(analysis.render_human(findings, stats))
    if over_budget:
        print(f"error: {stats['pragmas_used']} allowlist pragmas in use "
              f"(budget: {args.max_pragmas}) — fix sites instead of "
              "suppressing them", file=sys.stderr)
    return 1 if (findings or over_budget) else 0


if __name__ == "__main__":
    sys.exit(main())
