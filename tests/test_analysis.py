"""Fixture tests for the substrate invariant linter (``repro.analysis``).

Each pass gets a violation fixture that must trip its exact rule id and a
clean twin that must not; the pragma machinery gets suppression +
missing-justification coverage; and the end-to-end test asserts the real
tree under ``src/repro`` is clean with at most the pragma budget in use —
the same gate CI's ``lint`` job enforces.
"""

from __future__ import annotations

import pathlib
import textwrap

import pytest

from repro import analysis
from repro.analysis.core import PRAGMA_RULE

REPO = pathlib.Path(__file__).resolve().parents[1]


def lint_source(tmp_path, source: str, *, select=None, name="mod.py"):
    path = tmp_path / name
    path.write_text(textwrap.dedent(source))
    findings, stats = analysis.run(tmp_path, select=select, files=[path])
    return [f.rule for f in findings], findings, stats


def rules_of(tmp_path, source, select):
    return lint_source(tmp_path, source, select=select)[0]


# -- dispatch discipline ------------------------------------------------------

def test_dispatch_in_loop_trips(tmp_path):
    rules = rules_of(tmp_path, """
        def sweep(net, queries, eps):
            out = []
            for q in queries:
                out.append(net.range_query(q, eps))
            return out
        """, ["dispatch"])
    assert rules == ["dispatch-in-loop"]


def test_dispatch_in_comprehension_trips(tmp_path):
    rules = rules_of(tmp_path, """
        def sweep(net, queries, eps):
            return [net.range_query(q, eps) for q in queries]
        """, ["dispatch"])
    assert rules == ["dispatch-in-loop"]


def test_dispatch_clean_engine_batch(tmp_path):
    rules = rules_of(tmp_path, """
        def sweep(engine, net, queries, eps):
            plans = [net.range_query_plan(eps) for _ in queries]
            return engine.run(plans, list(queries), eps)
        """, ["dispatch"])
    assert rules == []


def test_dispatch_iterable_source_not_flagged(tmp_path):
    # the FIRST generator's source runs once, not per iteration
    rules = rules_of(tmp_path, """
        def count(fleet, queries, eps):
            return sum(len(h) for h in fleet.batch(queries).range(eps))
        """, ["dispatch"])
    assert rules == []


def test_dispatch_jit_in_loop_trips(tmp_path):
    rules = rules_of(tmp_path, """
        import jax

        def embed(model, rows):
            fwd = jax.jit(model.forward)
            return [fwd(r) for r in rows]
        """, ["dispatch"])
    assert rules == ["dispatch-jit-in-loop"]


# -- trace safety -------------------------------------------------------------

def test_trace_host_branch_trips(tmp_path):
    rules = rules_of(tmp_path, """
        import jax

        @jax.jit
        def step(x):
            if x > 0:
                return x
            return -x
        """, ["trace"])
    assert rules == ["trace-host-branch"]


def test_trace_shape_branch_clean(tmp_path):
    rules = rules_of(tmp_path, """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            if x.ndim == 1:
                x = x[None, :]
            return jnp.where(x > 0, x, -x)
        """, ["trace"])
    assert rules == []


def test_trace_scan_body_is_a_region(tmp_path):
    """A lax.scan body is traced code even with no jit decorator anywhere
    — the PR-10 scan-mode wavefront enters the registry's jit cache this
    way, so branching on its carry must trip the host-branch rule."""
    rules = rules_of(tmp_path, """
        from jax import lax

        def body(carry, k):
            if carry > 0:
                carry = carry - k
            return carry, carry

        def drive(c0, ks):
            return lax.scan(body, c0, ks)
        """, ["trace"])
    assert rules == ["trace-host-branch"]


def test_trace_scan_body_static_config_callee_clean(tmp_path):
    """Closure config objects forwarded from a scan body into a one-hop
    callee stay static there: branch-on-config is not branch-on-traced."""
    rules = rules_of(tmp_path, """
        import jax.numpy as jnp
        from jax import lax

        def attend(x, cfg):
            if cfg.gated:
                x = x * 2.0
            return jnp.tanh(x)

        def drive(c0, ks, cfg):
            def body(carry, k):
                return attend(carry, cfg), k
            return lax.scan(body, c0, ks)
        """, ["trace"])
    assert rules == []


def test_trace_concretize_and_numpy_trip(tmp_path):
    rules = rules_of(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            y = float(x)
            return np.sum(x) + y
        """, ["trace"])
    assert set(rules) == {"trace-concretize", "trace-numpy-call"}


def test_trace_fresh_jit_trips_and_cache_clean(tmp_path):
    rules = rules_of(tmp_path, """
        import jax

        def hot(g, x):
            fn = jax.jit(g)
            return fn(x)
        """, ["trace"])
    assert rules == ["trace-fresh-jit"]
    rules = rules_of(tmp_path, """
        import jax

        _CACHE = {}

        def hot(g, x):
            if id(g) not in _CACHE:
                _CACHE[id(g)] = jax.jit(g)
            return _CACHE[id(g)](x)
        """, ["trace"], )
    assert rules == []


def test_trace_aot_lower_clean(tmp_path):
    rules = rules_of(tmp_path, """
        import jax

        def lower(g, x):
            fn = jax.jit(g)
            return fn.lower(x)
        """, ["trace"])
    assert rules == []


def test_trace_static_unhashable_trips(tmp_path):
    rules = rules_of(tmp_path, """
        import jax
        from functools import partial

        @partial(jax.jit, static_argnums=(1,))
        def f(x, dims):
            return x.sum(dims)

        def call(x):
            return f(x, [0, 1])
        """, ["trace"])
    assert rules == ["trace-static-unhashable"]


def test_trace_static_rebound_trips(tmp_path):
    rules = rules_of(tmp_path, """
        import jax
        from functools import partial

        @partial(jax.jit, static_argnums=(1,))
        def f(x, cap):
            return x[:cap]

        def drive(x, cap):
            while True:
                out = f(x, cap)
                if out.shape[0] <= cap:
                    break
                cap *= 2
            return out
        """, ["trace"])
    assert rules == ["trace-static-rebound"]


# -- accounting soundness -----------------------------------------------------

def test_acct_raw_kernel_call_trips(tmp_path):
    rules = rules_of(tmp_path, """
        from repro.kernels import registry

        def raw(xs, ys):
            spec = registry.get("levenshtein")
            return spec.batch(xs, ys)
        """, ["accounting"])
    assert rules == ["acct-raw-kernel-call"]


def test_acct_counted_path_clean(tmp_path):
    rules = rules_of(tmp_path, """
        def counted(counter, xs, ys):
            return counter.eval_batch(xs, ys, bucket="query")
        """, ["accounting"])
    assert rules == []


def test_acct_padded_reduction_trips_and_slice_clean(tmp_path):
    rules = rules_of(tmp_path, """
        from repro.kernels.dispatch import pad_ragged_rows

        def total(rows):
            padded, lens = pad_ragged_rows(rows)
            return padded.sum()
        """, ["accounting"])
    assert rules == ["acct-padded-slice"]
    rules = rules_of(tmp_path, """
        from repro.kernels.dispatch import pad_ragged_rows

        def total(rows):
            padded, lens = pad_ragged_rows(rows)
            true = padded[: len(rows)]
            return true.sum()
        """, ["accounting"])
    assert rules == []


# -- sentinel overflow --------------------------------------------------------

def test_sentinel_unclamped_arith_trips(tmp_path):
    rules = rules_of(tmp_path, """
        from repro.distances._wavefront import BIG

        def bump(row):
            return row + BIG
        """, ["sentinel"])
    assert rules == ["sentinel-unclamped-arith"]


def test_sentinel_clamped_clean(tmp_path):
    rules = rules_of(tmp_path, """
        import jax.numpy as jnp
        from repro.distances._wavefront import BIG

        def bump(row):
            return jnp.minimum(row + BIG, BIG)
        """, ["sentinel"])
    assert rules == []


# -- shim discipline ----------------------------------------------------------

def test_shim_missing_warn_trips(tmp_path):
    rules = rules_of(tmp_path, """
        class OldThing:
            \"\"\"Deprecated; use repro.retrieval.Retriever. Removed in v0.2.\"\"\"

            def __init__(self):
                self.x = 1
        """, ["shims"])
    assert rules == ["shim-missing-warn"]


def test_shim_missing_docstring_trips(tmp_path):
    rules = rules_of(tmp_path, """
        from repro.core._deprecation import warn_legacy

        class OldThing:
            \"\"\"Deprecated thing.\"\"\"

            def __init__(self):
                warn_legacy("OldThing")
        """, ["shims"])
    assert rules == ["shim-docstring"]


def test_shim_compliant_clean(tmp_path):
    rules = rules_of(tmp_path, """
        from repro.core._deprecation import warn_legacy

        class OldThing:
            \"\"\"Deprecated; use repro.retrieval.Retriever instead.

            This shim will be removed in v0.2.
            \"\"\"

            def __init__(self):
                warn_legacy("OldThing")
        """, ["shims"])
    assert rules == []


# -- pragma machinery ---------------------------------------------------------

def test_pragma_suppresses_with_justification(tmp_path):
    rules, _, stats = lint_source(tmp_path, """
        def sweep(net, queries, eps):
            # lint: allow[dispatch-in-loop] -- sequential parity reference
            return [net.range_query(q, eps) for q in queries]
        """, select=["dispatch"])
    assert rules == []
    assert stats["pragmas_used"] == 1
    assert stats["pragmas"][0]["justification"] == \
        "sequential parity reference"


def test_pragma_without_justification_is_a_finding(tmp_path):
    rules, findings, _ = lint_source(tmp_path, """
        def sweep(net, queries, eps):
            # lint: allow[dispatch-in-loop]
            return [net.range_query(q, eps) for q in queries]
        """, select=["dispatch"])
    assert rules == [PRAGMA_RULE]


def test_pragma_for_other_rule_does_not_suppress(tmp_path):
    rules, _, _ = lint_source(tmp_path, """
        def sweep(net, queries, eps):
            # lint: allow[trace-host-branch] -- wrong rule entirely
            return [net.range_query(q, eps) for q in queries]
        """, select=["dispatch"])
    assert rules == ["dispatch-in-loop"]


def test_unknown_pass_selection_raises(tmp_path):
    (tmp_path / "m.py").write_text("x = 1\n")
    with pytest.raises(KeyError):
        analysis.run(tmp_path, select=["nope"])


# -- end to end: the tree ships clean -----------------------------------------

def test_src_repro_is_clean():
    findings, stats = analysis.run(REPO / "src" / "repro")
    assert findings == [], "\n" + "\n".join(f.format() for f in findings)
    assert stats["pragmas_used"] <= 10, stats["pragmas"]
    for p in stats["pragmas"]:
        assert p["justification"], p


def test_cli_exits_clean():
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint.py"),
         "--root", str(REPO / "src" / "repro"), "--format=json"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    import json
    payload = json.loads(proc.stdout)
    assert payload["clean"] is True
    assert set(payload["stats"]["passes"]) == set(analysis.pass_names())
