"""The `repro.retrieval` facade: config validation, registry round-trips,
deprecation shims, and — the acceptance property — hit-set and
``{query, build}`` eval-count parity between facade calls and the direct
PR-1/2/3 code paths on all four alignment distances."""

import json
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import _deprecation
from repro.core.counter import CountedDistance
from repro.core.matching import SubsequenceMatcher
from repro.core.refnet import ReferenceNet
from repro.distances import base as dist_base
from repro.distances import get
from repro.launch.elastic import ElasticIndex
from repro.retrieval import (
    RetrievalConfig, Retriever, distance_names, index_names,
    register_distance, register_index, resolve_index, unregister_distance,
    unregister_index)

RNG = np.random.default_rng(7)

#: the paper's four alignment distances; dtw is consistent-but-non-metric,
#: so it rides the linear-scan filter (paper §5)
DISTANCES = [("levenshtein", "refnet"), ("erp", "refnet"),
             ("frechet", "refnet"), ("dtw", "linear")]


def _strings(n, l=10, alphabet=12, rng=RNG):
    motifs = rng.integers(0, alphabet, size=(8, l))
    data = motifs[rng.integers(0, 8, n)]
    m = rng.random((n, l)) < 0.2
    return np.where(m, rng.integers(0, alphabet, size=(n, l)), data)


def _series(n, l=10, rng=RNG):
    steps = rng.normal(scale=0.3, size=(n, l, 2))
    return np.cumsum(steps, axis=1) + rng.normal(scale=1.5, size=(n, 1, 2))


def _seqs_and_query(dist_name, rng):
    """A tiny matching corpus with a planted fragment."""
    if get(dist_name).string:
        seqs = [rng.integers(0, 8, size=(60,)) for _ in range(3)]
        Q = rng.integers(0, 8, size=(24,))
    else:
        seqs = [np.cumsum(rng.normal(scale=0.3, size=(60, 2)), axis=0)
                for _ in range(3)]
        Q = np.cumsum(rng.normal(scale=0.3, size=(24, 2)), axis=0)
    Q[4:14] = seqs[0][8:18]
    return seqs, Q


def _quiet(fn, *a, **kw):
    """Run a legacy constructor without deprecation noise."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return fn(*a, **kw)


# -- config validation --------------------------------------------------------

def test_config_rejects_unknown_names():
    with pytest.raises(KeyError, match="unknown distance"):
        RetrievalConfig("nope")
    with pytest.raises(KeyError, match="unknown index kind"):
        RetrievalConfig("levenshtein", index="nope")


@pytest.mark.parametrize("kw,msg", [
    (dict(execution="turbo"), "execution"),
    (dict(backend="cuda"), "backend"),
    (dict(lam=1), "lam"),
    (dict(lam=8, lambda0=4), "lambda0"),
    (dict(lam=8, index="embedding"), "embedding"),
    (dict(execution="fleet"), "workers"),
    (dict(execution="fleet", workers=2, lam=8), "matching pipeline"),
    (dict(execution="fleet", workers=2, index="mv"), "refnet"),
    (dict(execution="fleet", workers=2, lb_cascade=True), "lb_cascade"),
    (dict(workers=("a",)), "fleet"),
])
def test_config_validation_errors(kw, msg):
    with pytest.raises(ValueError, match=msg):
        RetrievalConfig("levenshtein", **kw)


def test_config_rejects_nonmetric_on_indexed_path():
    with pytest.raises(ValueError, match="not a metric"):
        RetrievalConfig("dtw", lam=8, index="refnet")
    # the consistency-only path is fine (paper §5)
    RetrievalConfig("dtw", lam=8, index="linear")


def test_config_worker_count_normalization():
    cfg = RetrievalConfig("levenshtein", execution="fleet", workers=3)
    assert cfg.workers == ("w0", "w1", "w2")


def test_config_json_round_trip():
    cfg = RetrievalConfig("erp", lam=12, lambda0=2, index="covertree",
                          execution="host", tight_bounds=True,
                          eps_prime=0.5)
    again = RetrievalConfig.from_json(cfg.to_json())
    assert again == cfg
    # a Distance *instance* serializes by registry name
    inst = RetrievalConfig(get("frechet"), eps_prime=0.25)
    d = json.loads(inst.to_json())
    assert d["distance"] == "frechet"
    assert RetrievalConfig.from_json(inst.to_json()).dist is get("frechet")
    with pytest.raises(ValueError, match="unknown RetrievalConfig fields"):
        RetrievalConfig.from_dict({"distance": "erp", "warp": 9})


# -- registries ---------------------------------------------------------------

def test_distance_registry_round_trip():
    lev = get("levenshtein")

    @register_distance
    def _make():
        return dist_base.Distance(
            name="test_lev_clone", batch=lev.batch, matrix=lev.matrix,
            metric=True, consistent=True, string=True, variable_length=True)

    try:
        assert "test_lev_clone" in distance_names()
        data = _strings(40)
        r = Retriever.build(RetrievalConfig("test_lev_clone"), data)
        ref = Retriever.build(RetrievalConfig("levenshtein"), data)
        q = data[3]
        assert r.query(q).range(2.0).hits == ref.query(q).range(2.0).hits
    finally:
        unregister_distance("test_lev_clone")
    assert "test_lev_clone" not in distance_names()


def test_index_registry_round_trip():
    @register_index("test_linear_alias", requires_metric=False)
    def _make(dist, data, *, counter=None, **kw):
        from repro.core.matching import LinearScanIndex
        return LinearScanIndex(dist, data, counter=counter)

    try:
        assert "test_linear_alias" in index_names()
        assert resolve_index("test_linear_alias").factory is _make
        data = _strings(40)
        r = Retriever.build(
            RetrievalConfig("levenshtein", index="test_linear_alias"), data)
        ref = Retriever.build(
            RetrievalConfig("levenshtein", index="linear"), data)
        q = data[5]
        a, b = r.query(q).range(2.0), ref.query(q).range(2.0)
        assert a.hits == b.hits
        assert a.stats["query"] == b.stats["query"]
        # the custom kind also resolves inside the matching pipeline
        seqs, Q = _seqs_and_query("levenshtein", np.random.default_rng(0))
        rm = Retriever.build(
            RetrievalConfig("levenshtein", lam=8,
                            index="test_linear_alias"), seqs)
        rl = Retriever.build(
            RetrievalConfig("levenshtein", lam=8, index="linear"), seqs)
        assert rm.query(Q).range(1.0).hits == rl.query(Q).range(1.0).hits
    finally:
        unregister_index("test_linear_alias")
    assert "test_linear_alias" not in index_names()
    with pytest.raises(KeyError):
        resolve_index("test_linear_alias")


def test_register_index_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        register_index("refnet")(lambda *a, **k: None)


# -- deprecation shims --------------------------------------------------------

def test_legacy_constructors_warn():
    seqs, _ = _seqs_and_query("levenshtein", np.random.default_rng(1))
    with pytest.warns(DeprecationWarning, match="SubsequenceMatcher"):
        SubsequenceMatcher("levenshtein", 8)
    with pytest.warns(DeprecationWarning, match="ElasticIndex"):
        ElasticIndex("levenshtein", _strings(20), ["a", "b"])
    from repro.core.embedding_retrieval import EmbeddingRetriever
    with pytest.warns(DeprecationWarning, match="EmbeddingRetriever"):
        EmbeddingRetriever(np.eye(6, dtype=np.float32), meta=[None] * 6)


def test_facade_construction_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        seqs, Q = _seqs_and_query("levenshtein", np.random.default_rng(2))
        Retriever.build(RetrievalConfig("levenshtein", lam=8), seqs)
        Retriever.build(
            RetrievalConfig("levenshtein", execution="fleet", workers=2),
            _strings(30))


def test_legacy_shims_still_work():
    """The old constructors stay functional (thin shims over the same
    engines the facade drives)."""
    seqs, Q = _seqs_and_query("levenshtein", np.random.default_rng(3))
    m = _quiet(SubsequenceMatcher, "levenshtein", 8, 1).build(seqs)
    assert m.query_range(Q, 1.0)
    # distance arg accepts an instance OR a name on every path now
    m2 = _quiet(SubsequenceMatcher, get("levenshtein"), 8, 1).build(seqs)
    assert m2.query_range(Q, 1.0) == m.query_range(Q, 1.0)
    data = _strings(30)
    f1 = _quiet(ElasticIndex, "levenshtein", data, ["a", "b"])
    f2 = _quiet(ElasticIndex, get("levenshtein"), data, ["a", "b"])
    assert f1.range_query(data[0], 2.0) == f2.range_query(data[0], 2.0)


# -- facade vs direct paths: the acceptance property -------------------------

@pytest.mark.parametrize("dist_name,index", DISTANCES)
def test_matcher_parity_hits_and_counts(dist_name, index):
    """Facade (matcher mode) == direct SubsequenceMatcher: same hit sets,
    same {query, build} eval counts, same dispatches — on every alignment
    distance, both execution policies."""
    rng = np.random.default_rng(11)
    seqs, Q = _seqs_and_query(dist_name, rng)
    eps = 2.0 if get(dist_name).string else 1.0
    kw = dict(index=index, tight_bounds=(index == "refnet"))

    direct = _quiet(SubsequenceMatcher, dist_name, 8, 1, **kw).build(seqs)
    want = direct.query_range(Q, eps)
    want_counts = (direct.index.counter.count,
                   direct.index.counter.build_count,
                   direct.index.counter.dispatches)

    for execution in ("batched", "host"):
        r = Retriever.build(
            RetrievalConfig(dist_name, lam=8, lambda0=1,
                            execution=execution, **kw), seqs)
        rs = r.query(Q).range(eps)
        assert rs.hits == want, f"{dist_name}/{execution} hit drift"
        assert rs.stats["build"] == want_counts[1], \
            f"{dist_name}/{execution} build-count drift"
        if execution == "batched":
            assert rs.stats["query"] == want_counts[0]
            assert rs.stats["dispatches"] == want_counts[2]
        # types II/III agree end to end
        got_l = r.query(Q).longest(eps).first
        assert got_l == direct.query_longest(Q, eps)
    # and the host path spends exactly the legacy per-segment counts
    legacy = _quiet(SubsequenceMatcher, dist_name, 8, 1, batched=False,
                    **kw).build(seqs)
    legacy.query_range(Q, eps)
    rh = Retriever.build(
        RetrievalConfig(dist_name, lam=8, lambda0=1, execution="host", **kw),
        seqs)
    rsh = rh.query(Q).range(eps)
    assert rsh.stats["query"] == legacy.index.counter.count
    assert rsh.stats["dispatches"] == legacy.index.counter.dispatches


@pytest.mark.parametrize("dist_name", ["levenshtein", "erp", "frechet"])
def test_window_parity_hits_and_counts(dist_name):
    """Facade (window mode) == direct index range queries, host and
    engine execution, including the bulk_build=False legacy structure."""
    data = _strings(80) if get(dist_name).string else _series(80)
    eps = 2.0 if get(dist_name).string else 1.0
    queries = np.stack([data[i] for i in (3, 17, 40)])

    net = ReferenceNet(get(dist_name), data, eps_prime=1.0, num_max=4,
                       tight_bounds=True).build()
    want_build = net.counter.build_count
    net.counter.reset()
    want = [net.range_query(q, eps) for q in queries]
    want_count, want_disp = net.counter.count, net.counter.dispatches

    cfg = RetrievalConfig(dist_name, num_max=4, tight_bounds=True,
                          bulk_build=False)
    r = Retriever.build(cfg, data)
    host = r.batch(queries).via("host").range(eps)
    assert host.hits == want
    assert host.stats["query"] == want_count
    assert host.stats["dispatches"] == want_disp
    eng = r.batch(queries).via("batched").range(eps)
    assert eng.hits == want
    assert eng.stats["query"] == want_count
    assert eng.stats["dispatches"] <= want_disp
    assert r.eval_stats()["build"] == want_build


@pytest.mark.parametrize("dist_name",
                         ["levenshtein", "erp", "frechet", "euclidean"])
def test_fleet_parity_hits_and_counts(dist_name):
    """Facade (fleet mode) == direct ElasticIndex: same hits on the host
    loop AND the stacked device path, same {query, build} buckets."""
    data = _strings(60, l=8) if get(dist_name).string else _series(60, l=8)
    eps = 2.0 if get(dist_name).string else 1.0
    queries = np.stack([data[i] for i in (2, 31, 47)])
    workers = ["a", "b", "c"]

    direct = _quiet(ElasticIndex, dist_name, data, workers,
                    tight_bounds=True)
    want_host = [direct.range_query(q, eps, batched=False) for q in queries]
    want_stacked = direct.range_query_batch(queries, eps)
    want_buckets = direct.eval_count()

    r = Retriever.build(
        RetrievalConfig(dist_name, execution="fleet", workers=workers,
                        tight_bounds=True), data)
    host = r.batch(queries).via("host").range(eps)
    assert host.hits == want_host
    stacked = r.batch(queries).range(eps)
    assert stacked.hits == want_stacked
    assert stacked.hits == host.hits
    got = r.eval_stats()
    assert {k: got[k] for k in ("query", "build")} == want_buckets
    # dead-worker masking flows through the plan API
    masked = r.batch(queries).dead("a").range(eps)
    direct_masked = direct.range_query_batch(queries, eps, dead=("a",))
    assert masked.hits == direct_masked


def test_fleet_dead_mask_cleared_by_resize():
    """A masked worker that survives a resize serves again: resize hands
    every surviving shard a healthy (resharded) state, so a stale mask
    must not silently drop its partition from later answers."""
    data = _strings(60, l=8)
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet",
                        workers=["a", "b", "c"], tight_bounds=True), data)
    q = data[4]
    full = r.query(q).range(2.0).hits
    h = r.elastic().mark_dead("a")
    assert h.dead == ["a"]
    h.resize(["a", "b", "c"])
    assert h.dead == []
    assert r.query(q).range(2.0).hits == full
    # revive() also lifts the mask without a resize
    h.mark_dead("b").revive("b")
    assert h.dead == []


def test_config_to_json_rejects_unregistered_instance():
    lev = get("levenshtein")
    rogue = dist_base.Distance(
        name="never_registered", batch=lev.batch, matrix=lev.matrix,
        metric=True, consistent=True, string=True, variable_length=True)
    cfg = RetrievalConfig(rogue)
    with pytest.raises(ValueError, match="not in the registry"):
        cfg.to_json()


def test_fleet_elastic_handle_resize_parity():
    data = _strings(60, l=8)
    workers = ["a", "b", "c"]
    direct = _quiet(ElasticIndex, "levenshtein", data, workers,
                    tight_bounds=True)
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet", workers=workers,
                        tight_bounds=True), data)
    frac_d = direct.resize(["a", "b"])
    frac_f = r.elastic().resize(["a", "b"])
    assert frac_f == frac_d
    got, want = r.eval_stats(), direct.eval_count()
    assert {k: got[k] for k in ("query", "build")} == want
    q = data[4]
    assert r.query(q).range(2.0).hits == \
        direct.range_query_batch([q], 2.0)[0]
    assert r.elastic().workers == ["a", "b"]


# -- facade-only surfaces -----------------------------------------------------

def test_embedding_index_mode():
    rng = np.random.default_rng(5)
    vecs = rng.normal(size=(40, 8)).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    r = Retriever.build(
        RetrievalConfig("euclidean", index="embedding", eps_prime=0.05,
                        num_max=5, tight_bounds=True), vecs)
    rs = r.query(vecs[7]).range(1e-4)
    assert 7 in rs.hits
    near = r.query(vecs[7]).nearest(2.0, tol=1e-3)
    assert near.first == 7 and near.distances[0] <= 1e-4
    with pytest.raises(ValueError, match="embedding index expects"):
        Retriever.build(
            RetrievalConfig("euclidean", index="embedding"), _series(10))


def test_window_nearest_and_auto_eps():
    data = _strings(50)
    r = Retriever.build(RetrievalConfig("levenshtein"), data)
    rs = r.query(data[9]).nearest()     # no eps_max: auto-doubling
    assert rs.first == 9 and rs.distances[0] == 0.0
    with pytest.raises(ValueError, match="type II"):
        r.query(data[9]).longest(1.0)


def test_fleet_rejects_nonrange_queries_and_reset():
    data = _strings(40, l=8)
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet", workers=2), data)
    with pytest.raises(ValueError, match="range queries"):
        r.query(data[0]).nearest(1.0)
    with pytest.raises(ValueError, match="monotone"):
        r.reset_counter()
    with pytest.raises(ValueError, match="lb"):
        r.query(data[0]).lb().range(1.0)


def test_non_fleet_rejects_fleet_controls():
    data = _strings(30)
    r = Retriever.build(RetrievalConfig("levenshtein"), data)
    with pytest.raises(ValueError, match="fleet"):
        r.elastic()
    with pytest.raises(ValueError, match="dead"):
        r.query(data[0]).dead("a")


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1.0, 2.0, 4.0]))
    def test_facade_parity_property(seed, eps):
        """Property form of the acceptance criterion: random corpora keep
        facade and direct paths hit- and count-identical."""
        rng = np.random.default_rng(seed)
        data = _strings(50, rng=rng)
        net = ReferenceNet(get("levenshtein"), data, eps_prime=1.0).build()
        queries = data[rng.integers(0, len(data), 4)]
        net.counter.reset()
        want = [net.range_query(q, eps) for q in queries]
        wc = net.counter.count
        r = Retriever.build(
            RetrievalConfig("levenshtein", bulk_build=False), data)
        rs = r.batch(queries).range(eps)
        assert rs.hits == want and rs.stats["query"] == wc
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install .[dev])")
    def test_facade_parity_property():
        pass
