"""Per-architecture smoke tests (deliverable f): every assigned arch is
instantiated at a reduced config of the same family and runs one forward +
one train step on CPU, asserting output shapes and no NaNs; decode is
checked for exact consistency with the batched forward pass."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.models import registry
from repro.models.params import abstract_params, init_params, param_count
from repro.train import optimizer as opt_lib
from repro.train.train_state import make_train_step

ARCHS = registry.names()
RNG = np.random.default_rng(123)


def _batch(cfg, B=2, S=16):
    prefix = min(cfg.frontend_prefix, 4) if cfg.frontend != "none" else 0
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S - prefix)))
    labels = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)))
    batch = {"tokens": tokens, "labels": labels}
    if prefix:
        batch["embeds"] = jnp.asarray(
            RNG.normal(size=(B, prefix, cfg.d_model)), jnp.float32)
        labels = labels.at[:, :prefix].set(-1)
        batch["labels"] = labels
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, mod = registry.get(arch, reduced=True)
    params = init_params(mod.param_defs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    batch = _batch(cfg)
    out = mod.forward(params, batch, cfg)
    logits = out[0] if isinstance(out, tuple) else out
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.vocab_padded())
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg, mod = registry.get(arch, reduced=True)
    params = init_params(mod.param_defs(cfg), jax.random.PRNGKey(1),
                         jnp.float32)
    ocfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = opt_lib.init_state(params, ocfg)
    step = jax.jit(make_train_step(mod, cfg, ocfg))
    batch = _batch(cfg)
    p2, o2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(o2["step"]) == 1
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg, mod = registry.get(arch, reduced=True)
    if cfg.family == "moe":
        # capacity dropping differs between batched fwd and decode;
        # disable drops to compare the underlying function exactly
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    params = init_params(mod.param_defs(cfg), jax.random.PRNGKey(2),
                         jnp.float32)
    B, S = 2, 16
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)))
    out = mod.forward(params, {"tokens": tokens}, cfg)
    logits = out[0] if isinstance(out, tuple) else out
    pre = mod.forward(params, {"tokens": tokens[:, :S - 1]}, cfg,
                      return_cache=True)
    cache = pre[-1]

    def grow(k, x):
        if k in ("k", "v") or k.endswith("ckv") or k.endswith("kr"):
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 8)
            return jnp.pad(x, pad)
        return x

    cache = {k: (grow(k, v) if isinstance(v, jnp.ndarray) and v.ndim >= 3
                 else v) for k, v in cache.items()}
    lg, cache2 = mod.decode_step(params, cache, tokens[:, S - 1:S], cfg)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(logits[:, S - 1]),
        rtol=2e-2, atol=2e-3)
    assert int(cache2["pos"]) == S - 1


@pytest.mark.parametrize("arch,published_b,tol", [
    ("qwen2-72b", 72.7, 0.08),
    ("qwen2.5-32b", 32.8, 0.08),
    ("qwen3-4b", 4.0, 0.15),
    ("smollm-360m", 0.362, 0.15),
    ("mamba2-370m", 0.37, 0.20),
    ("zamba2-1.2b", 1.2, 0.25),
    ("deepseek-v2-236b", 236.0, 0.08),
    ("kimi-k2-1t-a32b", 1026.0, 0.10),
])
def test_param_count_matches_published(arch, published_b, tol):
    """Full-config parameter counts line up with the published sizes."""
    cfg, mod = registry.get(arch, reduced=False)
    n = param_count(mod.param_defs(cfg))
    assert abs(n / 1e9 - published_b) / published_b < tol, \
        f"{arch}: {n/1e9:.1f}B vs published {published_b}B"


@pytest.mark.parametrize("arch", ARCHS)
def test_abstract_params_no_allocation(arch):
    """Full configs are only ever touched abstractly (ShapeDtypeStruct)."""
    cfg, mod = registry.get(arch, reduced=False)
    ab = abstract_params(mod.param_defs(cfg, tp=16))
    n = sum(np.prod(x.shape) for x in jax.tree.leaves(ab))
    assert n > 1e8  # real scale, no memory allocated


def test_long_context_cells_require_sub_quadratic():
    """DESIGN §Arch-applicability: long_500k runs only for SSM/hybrid."""
    runnable = [a for a in ARCHS if registry.get(a)[0].sub_quadratic]
    assert sorted(runnable) == ["mamba2-370m", "zamba2-1.2b"]
    assert "long_500k" in SHAPES
