"""Tiered LB cascade: per-tier soundness (``lb <= exact``, property-tested),
precomputed envelope statistics, padded-row accounting audits, and hit-set +
``{query, build}`` count parity of cascade-on vs cascade-off across
matcher / window / fleet modes."""

import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip, deterministic ones still run
    HAVE_HYPOTHESIS = False

from repro.core.counter import CountedDistance
from repro.distances import bounds, get
from repro.kernels import dispatch as kernel_dispatch
from repro.kernels import registry as kernel_registry
from repro.retrieval import RetrievalConfig, Retriever

RNG = np.random.default_rng(77)

#: the four alignment distances; levenshtein carries only the endpoint tier
ALIGN = ["dtw", "erp", "frechet", "levenshtein"]
ENVELOPED = ["dtw", "erp", "frechet"]


def _ragged_batch(name, B=12, L=9, d=2, rng=RNG):
    """Row-paired ragged batch (padded arrays + true length vectors)."""
    if get(name).string:
        xs = rng.integers(0, 6, size=(B, L))
        ys = rng.integers(0, 6, size=(B, L))
    else:
        xs = rng.normal(scale=1.2, size=(B, L, d)).astype(np.float32)
        ys = rng.normal(scale=1.2, size=(B, L, d)).astype(np.float32)
    lx = rng.integers(2, L + 1, B)
    ly = rng.integers(2, L + 1, B)
    # garbage in the padding must never leak into a bound
    for a, ln in ((xs, lx), (ys, ly)):
        for i in range(B):
            a[i, ln[i]:] = 9.0 if a.dtype.kind in "iu" else 1e3
    return xs, ys, lx, ly


def _exact(name, xs, ys, lx, ly):
    return np.asarray(get(name).batch(xs, ys, lx, ly), np.float32)


# -- tier soundness: lb(x, y) <= delta(x, y) ---------------------------------


@pytest.mark.parametrize("name", ALIGN)
def test_endpoint_tier_sound(name):
    xs, ys, lx, ly = _ragged_batch(name)
    exact = _exact(name, xs, ys, lx, ly)
    lb = get(name).lower_bound(xs, ys, lx, ly)
    assert (lb <= exact + 1e-3).all(), f"{name} endpoint bound exceeds exact"


@pytest.mark.parametrize("name", ENVELOPED)
def test_envelope_tier_sound_and_gathered_equals_recomputed(name):
    xs, ys, lx, ly = _ragged_batch(name)
    exact = _exact(name, xs, ys, lx, ly)
    env_fn = get(name).envelope_bound
    lb = env_fn(xs, ys, lx, ly)
    assert (lb <= exact + 1e-3).all(), f"{name} envelope bound exceeds exact"
    # y_env-gathered statistics reproduce the recomputed bound exactly
    y_env = bounds.build_envelopes(ys, lens=ly)
    lb_g = env_fn(xs, ys, lx, ly, y_env=y_env.take(np.arange(len(ys))))
    np.testing.assert_allclose(lb_g, lb, rtol=1e-5, atol=1e-5)
    # ... and dominates nothing it shouldn't: still a valid bound
    assert (lb_g <= exact + 1e-3).all()


@pytest.mark.parametrize("name", ENVELOPED)
def test_one_direction_gathered_rows_sound(name):
    """The fleet/device form (stored boxes only) is a valid lower bound."""
    xs, ys, lx, ly = _ragged_batch(name)
    exact = _exact(name, xs, ys, lx, ly)
    e = bounds.build_envelopes(ys, lens=ly)
    lb = bounds.lb_envelope_rows(name, xs, lx, e.lo, e.hi, e.mass)
    assert (lb <= exact + 1e-3).all()


@pytest.mark.parametrize("name", ENVELOPED)
def test_device_envelope_spec_matches_host_bound(name):
    """The ``lb:<name>`` KernelSpec mirrors the numpy envelope bound."""
    xs, ys, lx, ly = _ragged_batch(name, B=6, L=7)
    host = get(name).envelope_bound(xs, ys, lx, ly)
    out = kernel_registry.get_envelope(name).batch(
        xs, ys, lx, ly, eps=np.full(6, 1.0, np.float32), interpret=True)
    np.testing.assert_allclose(np.asarray(out.dist), host,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(out.pruned), host > 1.0)


if HAVE_HYPOTHESIS:
    @st.composite
    def _series_pair(draw):
        lx = draw(st.integers(2, 8))
        ly = draw(st.integers(2, 8))
        d = draw(st.integers(1, 3))
        elems = st.floats(-4, 4, width=32)
        x = draw(st.lists(st.lists(elems, min_size=d, max_size=d),
                          min_size=lx, max_size=lx))
        y = draw(st.lists(st.lists(elems, min_size=d, max_size=d),
                          min_size=ly, max_size=ly))
        return (np.array(x, np.float32), np.array(y, np.float32))

    @settings(max_examples=40, deadline=None)
    @given(_series_pair(), st.sampled_from(ENVELOPED))
    def test_cascade_tiers_sound_property(pair, name):
        """Every tier's bound <= the exact distance on arbitrary pairs."""
        x, y = pair
        L = max(len(x), len(y))
        d = x.shape[1]
        xs = np.zeros((1, L, d), np.float32)
        ys = np.zeros((1, L, d), np.float32)
        xs[0, :len(x)] = x
        ys[0, :len(y)] = y
        lx = np.array([len(x)])
        ly = np.array([len(y)])
        exact = _exact(name, xs, ys, lx, ly)
        dist = get(name)
        assert dist.lower_bound(xs, ys, lx, ly)[0] <= exact[0] + 1e-3
        assert dist.envelope_bound(xs, ys, lx, ly)[0] <= exact[0] + 1e-3
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install .[dev])")
    def test_cascade_tiers_sound_property():
        pass


# -- envelope statistics: build / take / extend ------------------------------


def test_envelope_set_extend_matches_rebuild():
    a = RNG.normal(size=(6, 5, 2)).astype(np.float32)
    b = RNG.normal(size=(4, 8, 2)).astype(np.float32)  # longer windows
    ea = bounds.build_envelopes(a)
    ea.extend(bounds.build_envelopes(b))
    assert len(ea.mass) == 10 and ea.cum.shape[1] == 9
    np.testing.assert_allclose(ea.lo[6:], bounds.build_envelopes(b).lo,
                               rtol=1e-6)
    np.testing.assert_allclose(ea.mass[:6], bounds.build_envelopes(a).mass,
                               rtol=1e-6)
    # edge-padded prefix masses stay monotone and end at the total
    assert (np.diff(ea.cum, axis=1) >= -1e-6).all()
    np.testing.assert_allclose(ea.cum[np.arange(10), ea.lens], ea.mass,
                               rtol=1e-6)
    t = ea.take([7, 1])
    np.testing.assert_allclose(t.hi[0], ea.hi[7], rtol=1e-6)


def test_counter_extend_refreshes_envelope_cache():
    data = RNG.normal(size=(8, 6, 2)).astype(np.float32)
    c = CountedDistance(get("dtw"), data)
    before = c.envelopes()
    assert len(before.mass) == 8
    extra = RNG.normal(size=(3, 6, 2)).astype(np.float32)
    c.extend(extra)
    after = c.envelopes()
    assert len(after.mass) == 11
    np.testing.assert_allclose(
        after.mass[8:], bounds.build_envelopes(extra).mass, rtol=1e-6)


# -- cascade staging + accounting in the counter -----------------------------


def test_cascade_values_preserve_verdicts_and_counts():
    data = RNG.normal(size=(40, 8, 2)).astype(np.float32)
    c = CountedDistance(get("dtw"), data)
    idxs = np.arange(40)
    qs = np.repeat(data[3][None], 40, axis=0) \
        + RNG.normal(scale=0.2, size=(40, 8, 2)).astype(np.float32)
    exact = c.eval_stacked(qs, idxs, 8)
    eps = float(np.median(exact))
    c.reset()
    got = c.eval_stacked(qs, idxs, 8, eps=eps, lb_tier="envelope")
    # every <= eps verdict is preserved; pruned rows answer with a bound
    np.testing.assert_array_equal(got <= eps, exact <= eps)
    assert (got <= exact + 1e-3).all()
    np.testing.assert_allclose(got[got <= eps], exact[got <= eps], rtol=1e-5)
    # accounting: endpoint saw all 40 rows, exact only the survivors
    assert c.lb_tier_rows["endpoint"] == 40
    survivors = 40 - c.lb_tier_pruned["endpoint"]
    assert c.lb_tier_rows.get("envelope", 0) == survivors
    assert c.count == 40 - c.lb_tier_pruned["endpoint"] \
        - c.lb_tier_pruned.get("envelope", 0)
    assert c.lb_count == c.lb_tier_rows["endpoint"] \
        + c.lb_tier_rows.get("envelope", 0)


def test_cascade_exact_rows_opt_out_with_infinite_eps():
    """+inf rows (value-consuming EXACT frontiers) bypass every tier."""
    data = RNG.normal(size=(20, 6, 2)).astype(np.float32)
    c = CountedDistance(get("erp"), data)
    idxs = np.arange(20)
    qs = RNG.normal(size=(20, 6, 2)).astype(np.float32)
    exact = c.eval_stacked(qs, idxs, 6)
    c.reset()
    eps = np.full(20, 1e-6, np.float32)
    eps[::2] = np.inf          # 10 EXACT rows
    got = c.eval_stacked(qs, idxs, 6, eps=eps, lb_tier="envelope")
    np.testing.assert_allclose(got[::2], exact[::2], rtol=1e-5)
    assert c.lb_tier_rows["endpoint"] == 10   # finite-eps rows only
    assert c.count >= 10                      # all EXACT rows dispatched


def test_padded_rows_never_counted_in_packed_cascade():
    """Satellite audit: pow2 batch padding inside the kernel registry must
    not leak into lb_count, the per-tier maps, or DispatchStats."""
    B = 5                                      # pads to 8 inside spec.batch
    data = RNG.normal(size=(30, 6, 2)).astype(np.float32)
    c = CountedDistance(get("dtw"), data, backend="pallas")
    idxs = np.arange(B)
    qs = RNG.normal(size=(B, 6, 2)).astype(np.float32)
    kernel_dispatch.STATS.reset()
    c.eval_stacked(qs, idxs, 6, eps=0.5, lb_tier="envelope")
    assert c.lb_tier_rows["endpoint"] == B
    env_rows = c.lb_tier_rows.get("envelope", 0)
    assert env_rows <= B
    assert c.lb_count == B + env_rows
    assert c.count <= B
    # the dispatcher's per-tier stats count requested rows, not padded ones
    assert kernel_dispatch.STATS.lb_rows.get("envelope", 0) == env_rows
    assert kernel_dispatch.STATS.lb_pruned.get("envelope", 0) \
        == c.lb_tier_pruned.get("envelope", 0)


def test_packed_envelope_empty_batch_records_nothing():
    kernel_dispatch.STATS.reset()
    out = kernel_dispatch.packed_envelope(
        "dtw", np.zeros((0, 4, 2), np.float32), np.zeros((0, 4, 2),
                                                         np.float32),
        eps=1.0)
    assert len(np.asarray(out.dist)) == 0
    assert kernel_dispatch.STATS.lb_rows.get("envelope", 0) == 0


# -- parity: cascade-on == cascade-off, across modes -------------------------


def _series(n, l=8, rng=None):
    rng = rng or RNG
    steps = rng.normal(scale=0.3, size=(n, l, 2))
    return np.cumsum(steps, axis=1).astype(np.float32)


@pytest.mark.parametrize("name,index", [("dtw", "linear"),
                                        ("erp", "refnet"),
                                        ("frechet", "refnet")])
def test_window_mode_parity_all_tiers(name, index):
    data = _series(80)
    r = Retriever.build(RetrievalConfig(name, index=index), data)
    qs = data[[3, 40, 71]] + 0.05
    eps = 1.0
    r.reset_counter()
    off = r.batch(qs).via("batched").range(eps)
    for tier in ("endpoint", "envelope"):
        r.reset_counter()
        res = r.batch(qs).via("batched").lb(tier).range(eps)
        assert res.hits == off.hits, f"{name}/{tier} changed hits"
        assert res.stats["build"] == off.stats["build"]
        assert res.stats["query"] <= off.stats["query"], \
            f"{name}/{tier} increased exact evals"
        assert res.stats["lb"] > 0


def test_matcher_mode_parity():
    rng = np.random.default_rng(5)
    seqs = [np.cumsum(rng.normal(scale=0.3, size=(60, 2)),
                      axis=0).astype(np.float32) for _ in range(3)]
    Q = np.cumsum(rng.normal(scale=0.3, size=(24, 2)),
                  axis=0).astype(np.float32)
    Q[4:14] = seqs[0][8:18]
    r = Retriever.build(
        RetrievalConfig("dtw", lam=8, lambda0=2, index="linear"), seqs)
    off = r.query(Q).range(2.0)
    for tier in ("endpoint", "envelope"):
        res = r.query(Q).lb(tier).range(2.0)
        assert res.hits == off.hits, f"matcher/{tier} changed hits"


def test_fleet_mode_envelope_parity_and_stats():
    data = _series(60)
    qs = data[[2, 31, 47]]
    base = dict(execution="fleet", workers=["a", "b", "c"],
                tight_bounds=True)
    r_off = Retriever.build(RetrievalConfig("erp", **base), data)
    r_env = Retriever.build(
        RetrievalConfig("erp", lb_cascade="envelope", **base), data)
    eps = 1.0
    off = r_off.batch(qs).range(eps)
    env = r_env.batch(qs).range(eps)
    assert env.hits == off.hits
    stats = r_env.elastic().device_stats
    assert stats["lb_rows"] > 0
    assert stats["member_evals"] <= r_off.elastic().device_stats[
        "member_evals"]
    # per-call modifier: lb('off') disables the configured cascade
    again = r_env.batch(qs).lb("off").range(eps)
    assert again.hits == off.hits


def test_fleet_oneshot_device_cascade_parity():
    from repro.core.distributed import (device_range_query, flatten_net,
                                        host_reference_hits)
    from repro.core.refnet import ReferenceNet
    data = _series(48)
    net = ReferenceNet("erp", data, eps_prime=1.0, tight_bounds=True).build()
    flat = flatten_net(net)
    assert flat.envelopes is not None
    qs = data[[1, 17, 33]]
    eps = 1.0
    want = host_reference_hits(flat, qs, eps)
    hits_off, st_off = device_range_query(flat, qs, eps)
    hits_env, st_env = device_range_query(flat, qs, eps,
                                          lb_cascade="envelope")
    assert (hits_off == want).all() and (hits_env == want).all()
    assert st_env["lb_rows"] > 0
    assert st_env["member_evals"] <= st_off["member_evals"]


# -- config plumbing ---------------------------------------------------------


def test_config_tier_normalization_and_roundtrip():
    assert RetrievalConfig("dtw", index="linear").lb_cascade == "off"
    assert RetrievalConfig("dtw", index="linear",
                           lb_cascade=True).lb_cascade == "endpoint"
    cfg = RetrievalConfig("dtw", index="linear", lb_cascade="envelope")
    back = RetrievalConfig.from_json(cfg.to_json())
    assert back.lb_cascade == "envelope"
    assert json.loads(cfg.to_json())["lb_cascade"] == "envelope"
    with pytest.raises(ValueError, match="lb_cascade"):
        RetrievalConfig("dtw", index="linear", lb_cascade="sideways")


def test_fleet_config_accepts_envelope_rejects_endpoint():
    base = dict(execution="fleet", workers=2)
    RetrievalConfig("levenshtein", lb_cascade="envelope", **base)
    for bad in ("endpoint", True):
        with pytest.raises(ValueError, match="envelope"):
            RetrievalConfig("levenshtein", lb_cascade=bad, **base)
