"""Substrate: optimizer, checkpoint/resume, data pipeline, trainer
fault-tolerance, gradient compression, elastic resharding."""

import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, TokenBatcher, dedup_corpus
from repro.data.synthetic import token_corpus
from repro.launch.elastic import ElasticIndex, assign, moved_fraction
from repro.models import registry
from repro.models.params import init_params
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainerConfig


def test_adamw_converges_on_quadratic():
    cfg = opt_lib.OptConfig(lr=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}
    state = opt_lib.init_state(params, cfg)
    target = jnp.arange(64.0).reshape(8, 8) / 64.0

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2)
            + jnp.mean(p["b"] ** 2))(p)
        p, s, _ = opt_lib.apply_updates(p, g, s, cfg)
        return p, s, loss

    for _ in range(200):
        params, state, loss = step(params, state)
    assert float(loss) < 1e-3


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)


def test_schedule_shape():
    cfg = opt_lib.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(opt_lib.schedule(cfg, s)) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


def test_topk_compression_error_feedback():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)))
    r = jnp.zeros_like(g)
    sparse, r2 = opt_lib.topk_compress(g, r, keep_frac=0.25)
    nz = int(jnp.sum(sparse != 0))
    assert nz <= 17
    # error feedback: dropped mass is preserved in the residual
    np.testing.assert_allclose(np.asarray(sparse + r2), np.asarray(g),
                               rtol=1e-6)
    # second round flushes previously dropped coordinates
    sparse2, _ = opt_lib.topk_compress(jnp.zeros_like(g), r2, 0.25)
    assert float(jnp.sum(jnp.abs(sparse2))) > 0


def test_checkpoint_atomic_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    mgr.save(10, tree)
    mgr.save(20, jax.tree.map(lambda x: x * 2, tree))
    assert mgr.latest_step() == 20
    restored, meta = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(tree["w"]) * 2)
    assert meta["step"] == 20
    # retention
    mgr.save(30, tree)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [20, 30]


def test_checkpoint_crash_safety(tmp_path):
    """A stale temp dir (simulated crash) must not break save/restore."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = {"w": jnp.ones(3)}
    (tmp_path / ".tmp-99").mkdir()
    (tmp_path / ".tmp-99" / "garbage").write_text("partial write")
    mgr.save(99, tree)
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 99


def test_data_pipeline_determinism_and_sharding():
    corpus = token_corpus(64, 256, 1000, seed=3)
    b = TokenBatcher(corpus, batch=8, seq=32, seed=7)
    x1 = b.batch_at(5)
    x2 = b.batch_at(5)
    np.testing.assert_array_equal(x1["tokens"], x2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(x1["tokens"][:, 1:], x1["labels"][:, :-1])
    # shards partition the global batch
    shards = [TokenBatcher(corpus, 8, 32, seed=7, shard=i, n_shards=4)
              for i in range(4)]
    got = np.concatenate([s.batch_at(5)["tokens"] for s in shards])
    np.testing.assert_array_equal(got, x1["tokens"])


def test_prefetcher():
    corpus = token_corpus(16, 128, 100, seed=0)
    b = TokenBatcher(corpus, 4, 16, seed=0)
    pf = Prefetcher(b, start_step=3, depth=2)
    step, batch = pf.next()
    assert step == 3
    np.testing.assert_array_equal(batch["tokens"], b.batch_at(3)["tokens"])
    pf.close()


def test_trainer_checkpoints_and_resumes(tmp_path):
    cfg, mod = registry.get("smollm-360m", reduced=True)
    corpus = token_corpus(32, 96, cfg.vocab, seed=0)
    batcher = TokenBatcher(corpus, 2, 24, seed=0)
    ocfg = opt_lib.OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    tcfg = TrainerConfig(total_steps=6, ckpt_every=2, log_every=1)

    t1 = Trainer(mod, cfg, ocfg, batcher, tmp_path, tcfg)
    out1 = t1.run()
    assert out1["final_step"] == 6

    # simulated failure at step 3 of a fresh run, then resume
    ckpt2 = tmp_path / "run2"

    class Boom(RuntimeError):
        pass

    def injector(step):
        if step == 3:
            raise Boom()

    t2 = Trainer(mod, cfg, ocfg, batcher, ckpt2, tcfg,
                 failure_injector=injector)
    with pytest.raises(Boom):
        t2.run()
    t3 = Trainer(mod, cfg, ocfg, batcher, ckpt2, tcfg)
    params3, opt3, start3 = t3.init_or_resume()
    assert start3 == 3  # resumed from the emergency checkpoint
    out3 = t3.run()
    assert out3["final_step"] == 6
    # resumed run matches the uninterrupted run bit-for-bit (same stream)
    p1 = jax.tree.leaves(out1["params"])
    p3 = jax.tree.leaves(out3["params"])
    for a, b in zip(p1, p3):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_rendezvous_resharding_moves_little():
    ids = list(range(2000))
    w4 = [f"w{i}" for i in range(4)]
    w5 = w4 + ["w4"]
    a4 = assign(ids, w4)
    a5 = assign(ids, w5)
    frac = moved_fraction(a4, a5)
    assert 0.1 < frac < 0.3  # ~1/5 moves, everything else stays


def test_elastic_index_exactness_through_resize():
    from repro.data.synthetic import proteins
    data = proteins(300, seed=5)
    fleet = ElasticIndex("levenshtein", data, ["a", "b", "c"])
    q = data[17]
    want = fleet.range_query(q, 2.0)
    frac = fleet.resize(["a", "b", "c", "d"])
    assert 0 < frac < 0.6
    assert fleet.range_query(q, 2.0) == want
    fleet.resize(["a", "b"])
    assert fleet.range_query(q, 2.0) == want


def test_dedup_corpus_drops_near_duplicates():
    corpus = token_corpus(24, 64, 50, seed=2, dup_frac=0.3)
    kept = dedup_corpus(corpus, lam=16, eps=1.0, max_docs=24)
    assert len(kept) < len(corpus)
    # exact re-dedup of kept set removes nothing more
    kept2 = dedup_corpus(kept, lam=16, eps=0.0, max_docs=len(kept))
    assert len(kept2) == len(kept)
