"""End-to-end subsequence matching (paper §7): the 5-step pipeline vs brute
force, for all three query types, across distances and index backends."""

import numpy as np
import pytest

from repro.core import segmentation as seg
from repro.core.matching import (SubsequenceMatcher, brute_force_longest,
                                 brute_force_nearest, brute_force_range)
from repro.distances import get

RNG = np.random.default_rng(77)
LAM, L0 = 8, 1


def _plant_string_case(rng, mutate=True):
    seqs = [rng.integers(0, 8, size=(rng.integers(22, 30),)) for _ in range(3)]
    Q = rng.integers(0, 8, size=(20,))
    Q[3:13] = seqs[1][4:14]
    if mutate:
        Q[7] = (Q[7] + 1) % 8
    return Q, seqs


def _plant_series_case(rng):
    seqs = [np.cumsum(rng.normal(scale=0.3, size=(26, 2)), 0)
            for _ in range(2)]
    Q = np.cumsum(rng.normal(scale=0.3, size=(18, 2)), 0)
    Q[2:14] = seqs[0][6:18] + rng.normal(scale=0.01, size=(12, 2))
    return Q, seqs


def test_window_partition_lemma2():
    """Windows have length lambda//2 and tile the sequence."""
    x = np.arange(23)
    wins, meta = seg.partition_windows([x], LAM)
    assert wins.shape[1] == LAM // 2
    assert [w.start for w in meta] == [0, 4, 8, 12, 16]
    assert np.all(wins[2] == x[8:12])


def test_query_segments_band():
    Q = np.arange(12)
    buckets = seg.query_segments(Q, LAM, L0)
    assert sorted(buckets) == [3, 4, 5]
    arr, segs = buckets[4]
    assert len(segs) == 9  # |Q| - l + 1
    total = sum(len(s) for _, s in buckets.values())
    assert total <= (2 * L0 + 1) * len(Q)  # paper §5 bound


@pytest.mark.parametrize("index", ["refnet", "covertree", "mv", "linear"])
def test_type1_completeness_within_envelope(index):
    """Every |SX| = lambda pair (the Lemma-2-guaranteed envelope) is found."""
    dist = get("levenshtein")
    found_any = False
    for trial in range(4):
        rng = np.random.default_rng(100 + trial)
        Q, seqs = _plant_string_case(rng)
        m = SubsequenceMatcher("levenshtein", LAM, L0, index=index).build(seqs)
        got = {p.key() for p in m.query_range(Q, 1.0)}
        want = {p.key() for p in brute_force_range(
            dist, Q, seqs, LAM, L0, 1.0, x_len_exact=LAM)}
        assert want <= got, f"missing pairs: {sorted(want - got)[:5]}"
        found_any = found_any or bool(want)
        for p in got:
            pass  # keys only
        for p in m.query_range(Q, 1.0):
            assert p.distance <= 1.0
            assert p.x_len >= LAM and p.q_len >= LAM
            assert abs(p.x_len - p.q_len) <= L0
    assert found_any, "test cases never produced a planted match"


@pytest.mark.parametrize("dist_name", ["levenshtein", "erp", "frechet"])
def test_type2_longest_matches_brute_force(dist_name):
    for trial in range(4):
        rng = np.random.default_rng(200 + trial)
        if dist_name == "levenshtein":
            Q, seqs = _plant_string_case(rng)
            eps = 1.0
        else:
            Q, seqs = _plant_series_case(rng)
            eps = 0.5 if dist_name == "erp" else 0.25
        m = SubsequenceMatcher(dist_name, LAM, L0).build(seqs)
        got = m.query_longest(Q, eps)
        want = brute_force_longest(get(dist_name), Q, seqs, LAM, L0, eps)
        if want is None:
            assert got is None
        else:
            assert got is not None
            assert got.q_len == want.q_len
            assert got.distance <= eps


def test_type3_nearest_matches_brute_force():
    for trial in range(4):
        rng = np.random.default_rng(300 + trial)
        Q, seqs = _plant_string_case(rng, mutate=(trial % 2 == 0))
        m = SubsequenceMatcher("levenshtein", LAM, L0).build(seqs)
        got = m.query_nearest(Q, eps_max=10.0)
        want = brute_force_nearest(get("levenshtein"), Q, seqs, LAM, L0)
        assert got is not None
        assert got.distance == pytest.approx(want.distance, abs=1e-6)


def test_dtw_routes_to_linear_scan_only():
    Q, seqs = _plant_series_case(np.random.default_rng(5))
    with pytest.raises(ValueError, match="not a metric"):
        SubsequenceMatcher("dtw", LAM, L0, index="refnet")
    m = SubsequenceMatcher("dtw", LAM, L0, index="linear").build(seqs)
    res = m.query_range(Q, 0.5)
    for p in res:
        assert p.distance <= 0.5


def test_filter_cost_is_linear_in_Q_and_X():
    """Paper eq. (5): segment comparisons are O(|Q||X|), not O(|Q|^2|X|^2)."""
    rng = np.random.default_rng(9)
    seqs = [rng.integers(0, 8, size=(200,))]
    Q = rng.integers(0, 8, size=(40,))
    m = SubsequenceMatcher("levenshtein", LAM, L0, index="linear").build(seqs)
    m.reset_counter()
    m.segment_hits(Q, 1.0)
    n_windows = len(seqs[0]) // (LAM // 2)
    n_segments = sum(
        len(s) for _, s in seg.query_segments(Q, LAM, L0).values())
    assert m.eval_count == n_windows * n_segments
    bound = 2 * (2 * L0 + 1) / LAM * len(seqs[0]) * len(Q)
    assert m.eval_count <= bound * 1.1


def test_index_reduces_filter_cost():
    rng = np.random.default_rng(10)
    base = rng.integers(0, 20, size=(600,))
    seqs = [base]
    Q = np.concatenate([base[100:110], rng.integers(0, 20, size=(10,))])
    lin = SubsequenceMatcher("levenshtein", LAM, L0, index="linear").build(seqs)
    net = SubsequenceMatcher("levenshtein", LAM, L0, index="refnet",
                             tight_bounds=True).build(seqs)
    lin.reset_counter(); net.reset_counter()
    h1 = lin.segment_hits(Q, 1.0)
    h2 = net.segment_hits(Q, 1.0)
    assert {(h.segment, h.window_idx) for h in h1} == \
        {(h.segment, h.window_idx) for h in h2}
    assert net.eval_count < lin.eval_count
