"""The unified device-kernel substrate: registry jit-cache discipline,
packed ragged-bucket dispatch, fused ε-pruning, BIG-overflow clamping, and
hit-set + {query, build} eval-count parity of the packed pallas path vs the
host oracle across matcher / window / fleet modes."""

import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import _deprecation
from repro.core.batch_engine import BatchEngine
from repro.core.counter import CountedDistance
from repro.core.matching import LinearScanIndex
from repro.distances import get, np_backend
from repro.kernels import dispatch, ops, registry

RNG = np.random.default_rng(7)


def _strings(n, l=8, alphabet=10, rng=RNG):
    motifs = rng.integers(0, alphabet, size=(6, l))
    data = motifs[rng.integers(0, 6, n)]
    m = rng.random((n, l)) < 0.2
    return np.where(m, rng.integers(0, alphabet, size=(n, l)), data)


def _series(n, l=8, d=2, rng=RNG):
    steps = rng.normal(scale=0.3, size=(n, l, d))
    return np.cumsum(steps, axis=1) + rng.normal(scale=1.0, size=(n, 1, d))


def _ragged(name, B, Lx, Ly, rng, d=2):
    lx = rng.integers(1, Lx + 1, B)
    ly = rng.integers(1, Ly + 1, B)
    if get(name).string:
        xs = rng.integers(0, 6, size=(B, Lx))
        ys = rng.integers(0, 6, size=(B, Ly))
    else:
        xs = rng.normal(size=(B, Lx, d)).astype(np.float32)
        ys = rng.normal(size=(B, Ly, d)).astype(np.float32)
    # zero the padding tails (rows are only defined up to their lengths)
    for i in range(B):
        xs[i, lx[i]:] = 0
        ys[i, ly[i]:] = 0
    return xs, ys, lx, ly


# -- registry: one jit cache, one interpret policy ---------------------------


def test_registry_covers_the_distance_registry_keys():
    for name in ("dtw", "erp", "frechet", "levenshtein", "euclidean",
                 "hamming"):
        assert registry.has(name)
        assert registry.get(name).name == name
    assert registry.spec_for_mode("dfd").name == "frechet"
    with pytest.raises(KeyError):
        registry.get("nope")


def test_registry_no_retrace_on_repeat_shapes():
    """Satellite: layout+kernel jit once per shape class — repeat calls with
    the same shapes must NOT retrace (the old ops.py re-laid-out and
    re-resolved the backend per call)."""
    registry.clear_cache()
    xs = RNG.normal(size=(8, 6, 2)).astype(np.float32)
    ys = RNG.normal(size=(8, 7, 2)).astype(np.float32)
    spec = registry.get("dtw")
    spec.batch(xs, ys)
    t0 = registry.STATS["traces"]
    assert t0 >= 1
    spec.batch(xs, ys)
    spec.batch(xs * 2.0, ys - 1.0)       # same shapes, new values
    assert registry.STATS["traces"] == t0, "same-shape call retraced"
    # fused eps is an operand, not a static: still no retrace
    spec.batch(xs, ys, eps=1.0)
    assert registry.STATS["traces"] == t0
    # a genuinely new shape class traces exactly once more
    spec.batch(xs[:, :5], ys)
    assert registry.STATS["traces"] == t0 + 1


def test_ops_wavefront_no_retrace_on_repeat():
    xs, ys = _series(6, 5), _series(6, 5)
    ops.wavefront(xs, ys, "erp", interpret=True)
    t0 = registry.STATS["traces"]
    ops.wavefront(xs * 0.5, ys, "erp", interpret=True)
    assert registry.STATS["traces"] == t0


# -- satellite: BIG-sentinel overflow clamp ----------------------------------


def test_erp_big_clamp_long_high_gap_mass_series():
    """Quasi-infinity arithmetic must saturate at BIG, never run off to
    float32 inf/NaN: extreme gap masses blow up the ERP border cumsums
    (squares overflow -> inf gaps -> inf borders) without the clamps."""
    L = 48
    xs = np.full((8, L, 1), 1e25, np.float32)
    ys = np.full((8, L, 1), -1e25, np.float32)
    got = np.asarray(ops.wavefront(xs, ys, "erp", interpret=True))
    ref = np.asarray(ops.wavefront_ref(xs, ys, "erp"))
    assert np.isfinite(got).all(), "kernel leaked inf/NaN past the clamp"
    assert np.isfinite(ref).all(), "jnp oracle leaked inf/NaN past the clamp"
    np.testing.assert_allclose(got, ref, rtol=1e-4)
    # verdicts at any sane radius still reject, fused path included
    out = dispatch.packed_batch("erp", xs, ys, eps=1e6)
    assert not out.hit.any()
    assert np.isfinite(out.dist).all()


def test_dtw_big_clamp_stays_finite():
    xs = np.full((8, 32, 1), 3e24, np.float32)
    ys = -xs
    got = np.asarray(ops.wavefront(xs, ys, "dtw", interpret=True))
    assert np.isfinite(got).all()


# -- packed ragged-bucket dispatch -------------------------------------------


@pytest.mark.parametrize("name", ["dtw", "erp", "frechet", "levenshtein"])
def test_packed_dispatch_matches_numpy_oracle_ragged(name):
    rng = np.random.default_rng(11)
    xs, ys, lx, ly = _ragged(name, 11, 9, 7, rng)
    out = dispatch.packed_batch(name, xs, ys, lx, ly)
    want = np_backend.batch_for(name)(xs, ys, lx, ly)
    np.testing.assert_allclose(out.dist, want, rtol=1e-4, atol=1e-4)
    # bucket metadata reflects the sorted ragged layout
    meta = dispatch.STATS.last_meta
    assert meta is not None
    assert sum(c for _, _, c in meta.buckets) == 11


@pytest.mark.parametrize("name", ["levenshtein", "erp"])
def test_fused_eps_masks_and_certificates(name):
    rng = np.random.default_rng(3)
    B = 16
    if get(name).string:
        xs = rng.integers(0, 5, size=(B, 10))
        ys = np.where(rng.random((B, 10)) < 0.3,
                      rng.integers(0, 5, size=(B, 10)), xs)
    else:
        xs = rng.normal(size=(B, 10, 2)).astype(np.float32)
        ys = (xs + rng.normal(scale=0.4, size=xs.shape)).astype(np.float32)
    want = np_backend.batch_for(name)(xs, ys)
    u = np.unique(want)
    # threshold strictly between two achieved values: verdicts are stable
    eps = float(u[:2].mean()) if len(u) > 1 else float(u[0]) + 0.5
    out = dispatch.packed_batch(name, xs, ys, eps=eps)
    assert np.array_equal(out.hit, want <= eps)
    np.testing.assert_allclose(out.dist[out.hit], want[out.hit],
                               rtol=1e-4, atol=1e-4)
    # misses never materialize distances; prune certificates imply misses
    assert (out.dist[~out.hit] >= 3e37).all()
    assert not out.pruned[out.hit].any()


def test_counter_pallas_accepts_mixed_length_dispatches():
    """Acceptance: the old 'single length bucket per dispatch' ValueError
    path is gone, and padding rows never reach the eval counters."""
    data = _strings(16, l=8)
    dist = get("levenshtein")
    pal = CountedDistance(dist, data, backend="pallas")
    ref = CountedDistance(dist, data, backend="numpy")
    rng = np.random.default_rng(5)
    lens = rng.integers(4, 9, 12)
    qs = np.zeros((12, 8), data.dtype)
    for i, ln in enumerate(lens):
        qs[i, :ln] = data[i, :ln]
    idxs = rng.integers(0, len(data), 12)
    got = pal.eval_stacked(qs, idxs, q_len=lens)
    want = ref.eval_stacked(qs, idxs, q_len=lens)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # one dispatch, 12 exact evals — power-of-two padding rows not counted
    assert pal.dispatches == 1 and pal.count == 12


def test_packed_engine_one_dispatch_per_round_across_buckets():
    """Acceptance: BatchEngine goes from one dispatch per round per length
    bucket to one dispatch per round."""
    data = _strings(40, l=8)
    idx = LinearScanIndex(get("levenshtein"), data).build()
    rng = np.random.default_rng(9)
    rows = [data[i][:ln] for i, ln in
            zip(range(9), rng.integers(6, 9, 9))]
    n_buckets = len({len(r) for r in rows})
    assert n_buckets > 1
    idx.counter.reset()
    engine = BatchEngine(idx.counter)
    packed = engine.run([idx.range_query_plan(2.0) for _ in rows], rows, 2.0)
    assert engine.rounds == 1 and idx.counter.dispatches == 1
    # legacy per-bucket driving: one dispatch per bucket
    idx.counter.reset()
    legacy = []
    for ln in sorted({len(r) for r in rows}):
        sel = [r for r in rows if len(r) == ln]
        eng = BatchEngine(idx.counter)
        legacy.append((ln, eng.run(
            [idx.range_query_plan(2.0) for _ in sel], np.stack(sel), 2.0)))
    assert idx.counter.dispatches == n_buckets
    flat = {}
    for ln, res in legacy:
        flat[ln] = list(res)
    for r, hits in zip(rows, packed):
        assert hits == flat[len(r)].pop(0)


def test_fused_engine_hits_and_counts_match_host():
    """Fused ε on pallas engine rounds preserves hit sets AND the exact
    eval counts (pruning is a device-side wall-clock effect, not a count
    change)."""
    data = _strings(48, l=8)
    dist = get("levenshtein")
    host = LinearScanIndex(dist, data).build()
    queries = data[:5]
    host.counter.reset()
    want = [host.range_query(q, 2.0) for q in queries]
    want_count = host.counter.count

    pal = LinearScanIndex(
        dist, data, counter=CountedDistance(dist, data,
                                            backend="pallas")).build()
    pal.counter.reset()
    engine = BatchEngine(pal.counter)
    got = engine.run([pal.range_query_plan(2.0) for _ in queries],
                     queries, 2.0)
    assert got == want
    assert pal.counter.count == want_count


# -- deprecation shim ---------------------------------------------------------


def test_batch_dist_shim_warns_and_delegates():
    from repro.core import distributed
    xs = _series(4, 6)
    ys = _series(4, 6)
    with pytest.warns(DeprecationWarning, match="kernels.registry"):
        got = np.asarray(distributed._batch_dist("dtw", xs, ys))
    want = np.asarray(
        registry.get("dtw").device_call(xs, ys, interpret=True).dist)
    np.testing.assert_allclose(got, want, rtol=1e-5)
    # facade-style internal delegation stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        with _deprecation.facade_construction():
            distributed._batch_dist("euclidean", xs, ys)


# -- packed pallas path vs host oracle: matcher / window / fleet -------------


def _parity_window(dist_name, index, seed=0):
    from repro.retrieval import RetrievalConfig, Retriever
    rng = np.random.default_rng(seed)
    data = _strings(50, l=8, rng=rng) if get(dist_name).string \
        else _series(50, l=8, rng=rng)
    eps = 2.0 if get(dist_name).string else 1.0
    queries = [data[i][:ln] for i, ln in
               zip((3, 11, 27, 40), (6, 8, 7, 8))]
    cfg = dict(index=index, eps_prime=1.0, tight_bounds=(index == "refnet"))
    host = Retriever.build(RetrievalConfig(dist_name, **cfg), data)
    want = host.batch(queries).via("host").range(eps)
    pal = Retriever.build(
        RetrievalConfig(dist_name, kernel_backend="pallas", **cfg), data)
    got = pal.batch(queries).via("batched").range(eps)
    assert got.hits == want.hits, f"{dist_name}/{index} hit-set drift"
    assert got.stats["query"] == want.stats["query"]
    assert pal.eval_stats()["build"] == host.eval_stats()["build"]


@pytest.mark.parametrize("dist_name,index",
                         [("levenshtein", "refnet"), ("erp", "refnet"),
                          ("frechet", "linear"), ("dtw", "linear")])
def test_window_mode_packed_pallas_matches_host(dist_name, index):
    _parity_window(dist_name, index)


@pytest.mark.parametrize("dist_name,index",
                         [("levenshtein", "refnet"), ("erp", "linear"),
                          ("frechet", "linear"), ("dtw", "linear")])
def test_matcher_mode_packed_pallas_matches_host(dist_name, index):
    from repro.retrieval import RetrievalConfig, Retriever
    rng = np.random.default_rng(2)
    if get(dist_name).string:
        seqs = [rng.integers(0, 6, size=(30,)) for _ in range(2)]
        Q = rng.integers(0, 6, size=(14,))
        Q[2:10] = seqs[0][4:12]
        eps = 1.5
    else:
        seqs = [np.cumsum(rng.normal(scale=0.3, size=(30, 2)), 0)
                for _ in range(2)]
        Q = seqs[0][3:17] + rng.normal(scale=0.05, size=(14, 2))
        eps = 1.0
    cfg = dict(lam=8, lambda0=1, index=index, eps_prime=1.0)
    host = Retriever.build(
        RetrievalConfig(dist_name, execution="host", **cfg), seqs)
    want = host.query(Q).range(eps)
    pal = Retriever.build(
        RetrievalConfig(dist_name, kernel_backend="pallas", **cfg), seqs)
    got = pal.query(Q).range(eps)
    assert sorted(m.key() for m in got.hits) == \
        sorted(m.key() for m in want.hits)
    assert got.stats["query"] == want.stats["query"]
    assert pal.eval_stats()["build"] == host.eval_stats()["build"]


@pytest.mark.parametrize("dist_name", ["levenshtein", "erp", "frechet"])
def test_fleet_mode_packed_pallas_matches_host(dist_name):
    from repro.retrieval import RetrievalConfig, Retriever
    rng = np.random.default_rng(4)
    data = _strings(60, l=8, rng=rng) if get(dist_name).string \
        else _series(60, l=8, rng=rng)
    eps = 2.0 if get(dist_name).string else 1.0
    r = Retriever.build(
        RetrievalConfig(dist_name, execution="fleet", workers=2,
                        kernel_backend="pallas", tight_bounds=True), data)
    # mixed-length query batch: one packed device call, not one per bucket
    queries = [data[i][:ln] for i, ln in zip((1, 7, 22, 41), (7, 8, 8, 6))]
    want = r.batch(queries).via("host").range(eps)
    got = r.batch(queries).range(eps)
    assert got.hits == want.hits, f"{dist_name} fleet packed drift"
    assert r.eval_stats()["build"] > 0


# -- rectangular / multi-dim parity sweep (satellite) ------------------------


_SWEEP = [(1, 1, 1, 1), (3, 5, 9, 1), (4, 9, 5, 3), (6, 12, 12, 2),
          (5, 2, 11, 2)]

if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1),
           st.sampled_from(["dtw", "erp", "frechet", "levenshtein"]),
           st.integers(1, 3))
    def test_wavefront_rect_multidim_parity_property(seed, name, d):
        rng = np.random.default_rng(seed)
        B = int(rng.integers(1, 6))
        Lx = int(rng.integers(1, 11))
        Ly = int(rng.integers(1, 11))
        xs, ys, lx, ly = _ragged(name, B, Lx, Ly, rng, d=d)
        want = np_backend.batch_for(name)(xs, ys, lx, ly)
        out = dispatch.packed_batch(name, xs, ys, lx, ly)
        np.testing.assert_allclose(out.dist, want, rtol=1e-4, atol=1e-4)
else:
    @pytest.mark.parametrize("name", ["dtw", "erp", "frechet", "levenshtein"])
    @pytest.mark.parametrize("shape", _SWEEP)
    def test_wavefront_rect_multidim_parity_property(name, shape):
        B, Lx, Ly, d = shape
        rng = np.random.default_rng(B * 100 + Lx)
        xs, ys, lx, ly = _ragged(name, B, Lx, Ly, rng, d=d)
        want = np_backend.batch_for(name)(xs, ys, lx, ly)
        out = dispatch.packed_batch(name, xs, ys, lx, ly)
        np.testing.assert_allclose(out.dist, want, rtol=1e-4, atol=1e-4)
