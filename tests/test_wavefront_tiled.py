"""Tiled wavefront + scan twin: parity at adversarial band geometries.

The PR-10 contract: every execution mode of the wavefront DP — the banded
VMEM-blocked Pallas kernel at ANY tile setting, and the compiled
``lax.scan`` twin — produces bit-identical distances, hit sets, and
fused-ε prune certificates to the single-band (untiled) schedule and the
numpy host oracle, across ragged batches, all four alignment distances,
and multi-dim series.  Plus the policy plumbing: the ``REPRO_INTERPRET``
/ ``REPRO_KERNEL_EXEC`` env overrides, the ``default_tile`` VMEM
heuristic, the extended jit-cache key (zero retrace per
``(exec, tile)`` shape class), ``pairwise_l2``'s policy routing, and the
``RetrievalConfig`` fields that carry ``kernel_exec`` / ``kernel_tile``
down through the engines.
"""

import numpy as np
import pytest

from repro.distances import get, np_backend
from repro.kernels import dispatch, ops, registry
from repro.kernels.wavefront import band_layout

MODES4 = ["dtw", "erp", "frechet", "levenshtein"]

RNG = np.random.default_rng(13)


def _ragged(name, B, Lx, Ly, rng, d=2):
    lx = rng.integers(1, Lx + 1, B)
    ly = rng.integers(1, Ly + 1, B)
    if get(name).string:
        xs = rng.integers(0, 6, size=(B, Lx))
        ys = rng.integers(0, 6, size=(B, Ly))
    else:
        xs = rng.normal(size=(B, Lx, d)).astype(np.float32)
        ys = rng.normal(size=(B, Ly, d)).astype(np.float32)
    for i in range(B):
        xs[i, lx[i]:] = 0
        ys[i, ly[i]:] = 0
    return xs, ys, lx, ly


def _eps_mid(name, xs, ys, lx, ly):
    """A threshold strictly between achieved distances: stable verdicts."""
    want = np_backend.batch_for(name)(xs, ys, lx, ly)
    u = np.unique(want[np.isfinite(want)])
    return float(u[: max(2, len(u) // 2)].mean()) if len(u) > 1 \
        else float(u[0]) + 0.5


def _assert_same(got, base, ctx):
    np.testing.assert_array_equal(got.dist, base.dist, err_msg=ctx)
    np.testing.assert_array_equal(got.hit, base.hit, err_msg=ctx)
    np.testing.assert_array_equal(got.pruned, base.pruned, err_msg=ctx)


# -- band layout -------------------------------------------------------------


def test_band_layout_windows_match_full_slices():
    """Each band tile holds exactly the reversed-y stretch its diagonals
    read; clipped (pre-sequence) reads only ever feed masked cells, but
    the in-range part must be a verbatim copy."""
    rng = np.random.default_rng(0)
    Lx, Ly, T = 7, 6, 3
    Ypad = 2 * Lx + Ly + 1
    y = rng.normal(size=(2, Ypad)).astype(np.float32)
    bands = np.asarray(band_layout(y, Lx, Ly, T))
    K = Lx + Ly
    nbands = -(-K // T)
    Wb = Lx + T
    assert bands.shape == (2, nbands * Wb)
    for j in range(nbands):
        o = Lx + 1 + Ly - (j + 1) * T
        tile_j = bands[:, j * Wb:(j + 1) * Wb]
        lo = max(0, o)
        np.testing.assert_array_equal(
            tile_j[:, lo - o:], y[:, lo:o + Wb],
            err_msg=f"band {j} window drift")


# -- tiled / scan parity at adversarial band geometries ----------------------


@pytest.mark.parametrize("name", MODES4)
def test_tiled_parity_all_tiles_ragged(name):
    """dist/hit/pruned bit-identical across every band depth, the scan
    twin, and the numpy oracle — ragged rows spread across bands."""
    rng = np.random.default_rng(21)
    B, Lx, Ly = 9, 7, 6
    K = Lx + Ly
    xs, ys, lx, ly = _ragged(name, B, Lx, Ly, rng)
    eps = _eps_mid(name, xs, ys, lx, ly)
    spec = registry.get(name)
    eps_v = np.full(B, eps, np.float32)

    base = spec.batch(xs, ys, lx, ly, eps=eps_v, exec="pallas", tile=K)
    want = np_backend.batch_for(name)(xs, ys, lx, ly)
    np.testing.assert_array_equal(base.hit, want <= eps)
    np.testing.assert_allclose(base.dist[base.hit], want[base.hit],
                               rtol=1e-4, atol=1e-4)
    assert not base.pruned[base.hit].any()

    # len == tile, tile +- 1, one band, many bands, heuristic
    for tile in (1, 3, Lx, Ly, K - 1, K + 1, None):
        got = spec.batch(xs, ys, lx, ly, eps=eps_v,
                         exec="pallas", tile=tile)
        _assert_same(got, base, f"{name} tile={tile}")
    got = spec.batch(xs, ys, lx, ly, eps=eps_v, exec="scan")
    _assert_same(got, base, f"{name} scan")


@pytest.mark.parametrize("name", ["dtw", "erp"])
def test_tiled_parity_multidim_no_eps(name):
    """d=3 series, no ε: full distances equal across modes and tiles."""
    rng = np.random.default_rng(8)
    B, Lx, Ly = 6, 10, 9
    xs, ys, lx, ly = _ragged(name, B, Lx, Ly, rng, d=3)
    spec = registry.get(name)
    base = spec.batch(xs, ys, lx, ly, exec="pallas", tile=Lx + Ly)
    want = np_backend.batch_for(name)(xs, ys, lx, ly)
    np.testing.assert_allclose(base.dist, want, rtol=1e-4, atol=1e-4)
    for tile in (4, 5, Lx + Ly - 1):
        got = spec.batch(xs, ys, lx, ly, exec="pallas", tile=tile)
        _assert_same(got, base, f"{name} d=3 tile={tile}")
    _assert_same(spec.batch(xs, ys, lx, ly, exec="scan"), base,
                 f"{name} d=3 scan")


def test_tiled_parity_row_boundary_coincidences():
    """Rows whose answer diagonal lands exactly ON a band boundary (and
    one diagonal either side) — the ε-certificate-at-band-boundary rule
    must not leak verdicts early or late."""
    rng = np.random.default_rng(5)
    B, Lx, Ly, T = 6, 6, 6, 4
    xs = rng.normal(size=(B, Lx, 2)).astype(np.float32)
    ys = rng.normal(size=(B, Ly, 2)).astype(np.float32)
    # target diagonals lx+ly = 7, 8, 9 straddle the j=1 band end (8)
    lx = np.array([3, 4, 4, 4, 5, 6])
    ly = np.array([4, 4, 5, 4, 4, 3])
    for i in range(B):
        xs[i, lx[i]:] = 0
        ys[i, ly[i]:] = 0
    spec = registry.get("dtw")
    eps_v = np.full(B, _eps_mid("dtw", xs, ys, lx, ly), np.float32)
    base = spec.batch(xs, ys, lx, ly, eps=eps_v,
                      exec="pallas", tile=Lx + Ly)
    got = spec.batch(xs, ys, lx, ly, eps=eps_v, exec="pallas", tile=T)
    _assert_same(got, base, "boundary-coincident rows")
    _assert_same(spec.batch(xs, ys, lx, ly, eps=eps_v, exec="scan"),
                 base, "boundary-coincident rows (scan)")


def test_packed_dispatch_scan_matches_pallas_ragged():
    """The packed ragged-bucket dispatcher carries exec/tile through the
    bucket sort + scatter unchanged."""
    rng = np.random.default_rng(31)
    xs, ys, lx, ly = _ragged("erp", 11, 9, 7, rng)
    eps = _eps_mid("erp", xs, ys, lx, ly)
    base = dispatch.packed_batch("erp", xs, ys, lx, ly, eps=eps)
    for kw in (dict(exec="scan"), dict(exec="pallas", tile=3),
               dict(exec="pallas", tile=5)):
        got = dispatch.packed_batch("erp", xs, ys, lx, ly, eps=eps, **kw)
        _assert_same(got, base, f"packed {kw}")


# -- jit-cache discipline: (exec, tile) are key axes, zero retrace -----------


def test_no_retrace_per_exec_tile_shape_class():
    registry.clear_cache()
    rng = np.random.default_rng(3)
    xs = rng.normal(size=(8, 6, 2)).astype(np.float32)
    ys = rng.normal(size=(8, 7, 2)).astype(np.float32)
    spec = registry.get("dtw")
    variants = [dict(exec="pallas", tile=4), dict(exec="pallas", tile=5),
                dict(exec="scan")]
    traces_at = []
    for kw in variants:
        spec.batch(xs, ys, **kw)
        traces_at.append(registry.STATS["traces"])
    # distinct (exec, tile) classes each compiled something new
    assert traces_at[0] < traces_at[1] < traces_at[2]
    t0 = registry.STATS["traces"]
    for kw in variants:
        spec.batch(xs * 2.0, ys - 1.0, **kw)   # same shapes, new values
    assert registry.STATS["traces"] == t0, "warm tiled/scan sweep retraced"


def test_ops_wavefront_threads_exec_and_tile():
    rng = np.random.default_rng(9)
    xs = rng.normal(size=(5, 6, 2)).astype(np.float32)
    ys = rng.normal(size=(5, 6, 2)).astype(np.float32)
    base = ops.wavefront(xs, ys, "dtw", interpret=True)
    for kw in (dict(exec="scan"), dict(exec="pallas", tile=3)):
        got = ops.wavefront(xs, ys, "dtw", interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


# -- policy plumbing: env overrides, heuristic, pairwise_l2 ------------------


def test_repro_interpret_env_override(monkeypatch):
    prev = registry.set_default_interpret(None)
    try:
        monkeypatch.setenv("REPRO_INTERPRET", "0")
        registry.set_default_interpret(None)     # force re-resolution
        assert registry.default_interpret() is False
        monkeypatch.setenv("REPRO_INTERPRET", "yes")
        registry.set_default_interpret(None)
        assert registry.default_interpret() is True
        # the hook wins over the env var, and returns the previous pin
        assert registry.set_default_interpret(False) is True
        assert registry.default_interpret() is False
    finally:
        registry.set_default_interpret(prev)


def test_repro_kernel_exec_env_override(monkeypatch):
    prev = registry.set_default_exec(None)
    try:
        monkeypatch.setenv("REPRO_KERNEL_EXEC", "scan")
        registry.set_default_exec(None)
        assert registry.default_exec() == "scan"
        monkeypatch.setenv("REPRO_KERNEL_EXEC", "bogus")
        registry.set_default_exec(None)
        with pytest.raises(ValueError, match="REPRO_KERNEL_EXEC"):
            registry.default_exec()
        monkeypatch.delenv("REPRO_KERNEL_EXEC")
        registry.set_default_exec(None)
        assert registry.default_exec() == "pallas"
        with pytest.raises(ValueError, match="exec mode"):
            registry.set_default_exec("bogus")
        with pytest.raises(ValueError, match="exec mode"):
            registry.resolve_exec("bogus")
    finally:
        registry.set_default_exec(prev)


def test_default_tile_heuristic_bounds():
    # small shapes: one band (the untiled schedule — CI baselines stable)
    for Lx, Ly, d in [(6, 6, 1), (12, 12, 2), (20, 20, 3)]:
        assert registry.default_tile(Lx, Ly, d) == Lx + Ly
    # the clamp floor and ceiling hold everywhere, and the tile shrinks
    # monotonically as the budget tightens
    t_big = registry.default_tile(4096, 4096, 8)
    t_small = registry.default_tile(4096, 4096, 8, budget=1 << 16)
    assert 8 <= t_small <= t_big <= 8192
    assert t_small == 8        # starved budget bottoms out at the floor
    assert t_big < 8192        # long wide segments really do get banded


def test_pairwise_l2_follows_interpret_policy():
    from repro.kernels.pairwise_l2 import pairwise_l2_pallas
    from repro.kernels.ref import pairwise_l2_ref
    rng = np.random.default_rng(2)
    x = rng.normal(size=(8, 3)).astype(np.float32)
    y = rng.normal(size=(8, 3)).astype(np.float32)
    want = np.asarray(pairwise_l2_ref(x, y))
    # explicit override and policy default agree (policy resolves to
    # interpret=True off-TPU)
    got_explicit = np.asarray(
        pairwise_l2_pallas(x, y, bm=8, bn=8, interpret=True))
    got_policy = np.asarray(pairwise_l2_pallas(x, y, bm=8, bn=8))
    np.testing.assert_allclose(got_explicit, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(got_policy, got_explicit)


# -- config / engine plumbing ------------------------------------------------


def test_config_validates_and_roundtrips_kernel_exec_tile():
    from repro.retrieval import RetrievalConfig
    cfg = RetrievalConfig("dtw", index="linear", kernel_backend="pallas",
                          kernel_exec="scan", kernel_tile=6)
    again = RetrievalConfig.from_json(cfg.to_json())
    assert again == cfg
    assert again.kernel_exec == "scan" and again.kernel_tile == 6
    with pytest.raises(ValueError, match="kernel_exec"):
        RetrievalConfig("dtw", index="linear", kernel_exec="bogus")
    with pytest.raises(ValueError, match="kernel_tile"):
        RetrievalConfig("dtw", index="linear", kernel_tile=0)


@pytest.mark.parametrize("kw", [dict(kernel_exec="scan"),
                                dict(kernel_tile=4),
                                dict(kernel_exec="scan", kernel_tile=4)])
def test_window_mode_scan_and_tile_match_host(kw):
    """Facade-level: hit sets AND eval counts identical to the host loop
    when the engines run the scan backend / an explicit band depth."""
    from repro.retrieval import RetrievalConfig, Retriever
    rng = np.random.default_rng(17)
    steps = rng.normal(scale=0.3, size=(40, 8, 2))
    data = np.cumsum(steps, axis=1) + rng.normal(size=(40, 1, 2))
    queries = [data[i][:ln] for i, ln in zip((3, 11, 27), (6, 8, 7))]
    host = Retriever.build(
        RetrievalConfig("dtw", index="linear"), data)
    want = host.batch(queries).via("host").range(1.0)
    dev = Retriever.build(
        RetrievalConfig("dtw", index="linear", kernel_backend="pallas",
                        **kw), data)
    got = dev.batch(queries).via("batched").range(1.0)
    assert got.hits == want.hits, f"{kw} hit-set drift"
    assert got.stats["query"] == want.stats["query"]


def test_fleet_mode_scan_matches_host():
    from repro.retrieval import RetrievalConfig, Retriever
    rng = np.random.default_rng(23)
    motifs = rng.integers(0, 10, size=(6, 8))
    data = motifs[rng.integers(0, 6, 60)]
    m = rng.random((60, 8)) < 0.2
    data = np.where(m, rng.integers(0, 10, size=(60, 8)), data)
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet", workers=2,
                        kernel_backend="pallas", kernel_exec="scan",
                        tight_bounds=True), data)
    queries = [data[i][:ln] for i, ln in zip((1, 7, 22), (7, 8, 6))]
    want = r.batch(queries).via("host").range(2.0)
    got = r.batch(queries).range(2.0)
    assert got.hits == want.hits, "fleet scan-backend hit drift"
