"""Shared test fixtures — the runtime sanitizer lane.

``REPRO_SANITIZE=1`` wraps every test in ``jax.checking_leaks()``, which
raises on tracer leaks (a traced value escaping its jit region — the
runtime complement of the static ``trace`` lint pass).  CI's sanitizer
lane runs the kernel-registry and bounds-cascade suites under this plus
``JAX_DEBUG_NANS=1``; locally it is off by default because leak checking
disables some caching and slows tracing down.
"""

from __future__ import annotations

import contextlib
import os

import pytest


@pytest.fixture(autouse=True)
def _sanitize_tracer_leaks():
    if os.environ.get("REPRO_SANITIZE") != "1":
        yield
        return
    import jax
    with contextlib.ExitStack() as stack:
        stack.enter_context(jax.checking_leaks())
        yield
