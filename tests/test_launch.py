"""Launch layer: sharding rule resolution, axis fitting, input specs, and
the HLO cost parser (trip-count-weighted)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.launch import sharding as shd
from repro.roofline.hlo_costs import parse_hlo_costs


class FakeMesh:
    """Stands in for jax Mesh: only .shape and .axis_names are consulted."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH_SP = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_rules_drop_missing_axes():
    s = shd.spec(MESH_SP, shd.TRAIN_RULES, "batch", None, "tensor")
    assert s == P("data", None, "model")
    s = shd.spec(MESH_MP, shd.TRAIN_RULES, "batch", None, "tensor")
    assert s == P(("pod", "data"), None, "model")


def test_fit_axes_prunes_indivisible_dims():
    # batch 1 cannot shard at all
    s = shd.spec(MESH_MP, shd.TRAIN_RULES, "batch", shape=(1,))
    assert s == P(None)
    # batch 2 keeps only the pod axis (2 divides, 16 doesn't divide 1)
    s = shd.spec(MESH_MP, shd.TRAIN_RULES, "batch", shape=(2,))
    assert s == P("pod")
    # batch 64 keeps both (2*16 divides 64)
    s = shd.spec(MESH_MP, shd.TRAIN_RULES, "batch", shape=(64,))
    assert s == P(("pod", "data"))
    # vocab not divisible by model axis -> unsharded
    s = shd.spec(MESH_SP, shd.TRAIN_RULES, "tensor", shape=(50280,))
    assert s == P(None)


def test_serve_rules_shard_cache_length():
    s = shd.spec(MESH_SP, shd.SERVE_RULES, "batch", "kv_seq", None,
                 shape=(128, 32768, 8))
    assert s == P("data", "model", None)
    # train rules never shard kv length
    s = shd.spec(MESH_SP, shd.TRAIN_RULES, "kv_seq", shape=(32768,))
    assert s == P(None)


def test_shape_grid_complete():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288


def test_hlo_cost_parser_weights_trip_counts():
    """scan(matmul, length=10) must cost ~10x one matmul after weighting."""

    def scanned(x, w):
        def step(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(step, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = jax.jit(scanned).lower(x, w).compile()
    costs = parse_hlo_costs(comp.as_text())
    one_matmul = 2 * 64 * 128 * 128
    assert costs["flops"] == pytest.approx(10 * one_matmul, rel=0.01), costs
    # XLA's own analysis counts the body once — our parser must not
    xla = comp.cost_analysis()
    if isinstance(xla, list):  # older jax returns [dict], newer a dict
        xla = xla[0] if xla else {}
    assert costs["flops"] > 5 * xla.get("flops", 0)


def test_hlo_cost_parser_collectives_smoke():
    """A psum under shard_map produces all-reduce bytes (1-device mesh)."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.experimental.shard_map import shard_map

    def f(a):
        return shard_map(lambda v: jax.lax.psum(v, "x"), mesh=mesh,
                         in_specs=P("x"), out_specs=P())(a)

    a = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    with mesh:
        comp = jax.jit(f).lower(a).compile()
    costs = parse_hlo_costs(comp.as_text())
    assert costs["collectives"]["total_bytes"] >= 0  # parser doesn't crash


def test_dryrun_reports_exist_and_pass():
    """The committed dry-run artifacts: every runnable cell ok on both
    meshes, skips only for the documented long_500k cells."""
    import json
    import pathlib
    d = pathlib.Path(__file__).resolve().parents[1] / "reports" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run artifacts not generated yet")
    recs = [json.loads(p.read_text()) for p in d.glob("*.json")]
    assert recs, "no dry-run artifacts"
    bad = [r for r in recs if r["status"] == "error"]
    assert not bad, [f"{r['arch']}x{r['shape']}x{r['mesh']}" for r in bad]
    skips = [r for r in recs if r["status"] == "skipped"]
    for r in skips:
        assert r["shape"] == "long_500k"
    multi = [r for r in recs if r["mesh"] == "pod2x16x16"
             and r["status"] == "ok"]
    assert len(multi) >= 30  # the pod axis shards every runnable cell
