"""Batched frontier engine: bit-identical hit sets and exact-eval counts vs
the host-mode pair-at-a-time reference, across indexes and all four
alignment distances; LB-cascade soundness; backend parity."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.batch_engine import BatchEngine
from repro.core.counter import CountedDistance
from repro.core.covertree import CoverTree
from repro.core.matching import LinearScanIndex, SubsequenceMatcher
from repro.core.refindex import MVReferenceIndex
from repro.core.refnet import ReferenceNet
from repro.distances import get

RNG = np.random.default_rng(7)


def _strings(n, l=10, alphabet=12, rng=RNG):
    motifs = rng.integers(0, alphabet, size=(8, l))
    data = motifs[rng.integers(0, 8, n)]
    m = rng.random((n, l)) < 0.2
    return np.where(m, rng.integers(0, alphabet, size=(n, l)), data)


def _series(n, l=10, rng=RNG):
    steps = rng.normal(scale=0.3, size=(n, l, 2))
    return np.cumsum(steps, axis=1) + rng.normal(scale=1.5, size=(n, 1, 2))


def _build(index, dist_name, data):
    dist = get(dist_name)
    if index == "refnet":
        return ReferenceNet(dist, data, eps_prime=1.0, num_max=4,
                            tight_bounds=True).build()
    if index == "covertree":
        return CoverTree(dist, data, eps_prime=1.0).build()
    if index == "mv":
        return MVReferenceIndex(dist, data, n_refs=4).build()
    return LinearScanIndex(dist, data).build()


# (index, distance): dtw is consistent-but-non-metric, so only linear scan
# may carry it (paper §5); the metric indexes cover the other three.
COMBOS = [
    ("refnet", "levenshtein"), ("refnet", "erp"), ("refnet", "frechet"),
    ("covertree", "levenshtein"), ("covertree", "erp"),
    ("mv", "levenshtein"), ("mv", "frechet"),
    ("linear", "dtw"), ("linear", "levenshtein"), ("linear", "erp"),
    ("linear", "frechet"),
]


@pytest.mark.parametrize("index,dist_name", COMBOS)
def test_engine_matches_host_hits_and_counts(index, dist_name):
    """The acceptance property: identical hit sets AND exact-evaluation
    counts vs sequential host-mode traversal, with fewer dispatches."""
    data = _strings(120) if get(dist_name).string else _series(120)
    idx = _build(index, dist_name, data)
    eps = 2.0 if get(dist_name).string else 1.0
    queries = np.stack([data[i] for i in (3, 17, 40, 77, 101)])

    idx.counter.reset()
    host_hits = [idx.range_query(q, eps) for q in queries]
    host_count, host_disp = idx.counter.count, idx.counter.dispatches

    idx.counter.reset()
    engine = BatchEngine(idx.counter)
    plans = [idx.range_query_plan(eps) for _ in queries]
    eng_hits = engine.run(plans, queries, eps)

    assert eng_hits == host_hits
    assert idx.counter.count == host_count
    # one dispatch per merged round, not one per (query, frontier)
    assert idx.counter.dispatches <= host_disp
    if host_disp > engine.rounds:
        assert idx.counter.dispatches < host_disp
    assert idx.counter.dispatches <= engine.rounds


@pytest.mark.parametrize("dist_name", ["dtw", "erp", "frechet", "levenshtein"])
def test_lower_bounds_never_exceed_exact(dist_name):
    dist = get(dist_name)
    assert dist.lower_bound is not None
    rng = np.random.default_rng(3)
    for lx, ly in [(4, 4), (3, 9), (10, 6)]:
        if dist.string:
            xs = rng.integers(0, 6, size=(32, lx))
            ys = rng.integers(0, 6, size=(32, ly))
        else:
            xs = rng.normal(size=(32, lx, 2)).astype(np.float32)
            ys = rng.normal(size=(32, ly, 2)).astype(np.float32)
        lxv = np.full(32, lx)
        lyv = np.full(32, ly)
        lbs = np.asarray(dist.lower_bound(xs, ys, lxv, lyv))
        from repro.distances import np_backend
        L = max(lx, ly)

        def pad(a):
            w = [(0, 0), (0, L - a.shape[1])] + [(0, 0)] * (a.ndim - 2)
            return np.pad(a, w)

        exact = np.asarray(np_backend.batch_for(dist_name)(
            pad(xs), pad(ys), lxv, lyv))
        assert np.all(lbs <= exact + 1e-4), \
            f"{dist_name}: lb exceeded exact at {np.argmax(lbs - exact)}"


@pytest.mark.parametrize("index,dist_name",
                         [("refnet", "erp"), ("linear", "dtw"),
                          ("mv", "levenshtein")])
def test_lb_cascade_prunes_without_changing_hits(index, dist_name):
    data = _strings(100) if get(dist_name).string else _series(100)
    idx = _build(index, dist_name, data)
    eps = 2.0 if get(dist_name).string else 0.75
    queries = np.stack([data[i] for i in (5, 33, 66)])

    idx.counter.reset()
    plain = BatchEngine(idx.counter).run(
        [idx.range_query_plan(eps) for _ in queries], queries, eps)
    base_count = idx.counter.count

    idx.counter.reset()
    cascaded = BatchEngine(idx.counter, lb_cascade=True).run(
        [idx.range_query_plan(eps) for _ in queries], queries, eps)
    assert cascaded == plain
    assert idx.counter.count <= base_count
    assert idx.counter.lb_count > 0


def test_matcher_batched_step4_matches_legacy_loop():
    rng = np.random.default_rng(11)
    seqs = [rng.integers(0, 8, size=(60,)) for _ in range(3)]
    Q = rng.integers(0, 8, size=(24,))
    Q[4:14] = seqs[0][8:18]
    kw = dict(index="refnet", tight_bounds=True)
    batched = SubsequenceMatcher("levenshtein", 8, 1, **kw).build(seqs)
    legacy = SubsequenceMatcher("levenshtein", 8, 1, batched=False,
                                **kw).build(seqs)
    batched.reset_counter()
    legacy.reset_counter()
    hb = {(h.segment, h.window_idx) for h in batched.segment_hits(Q, 1.0)}
    hl = {(h.segment, h.window_idx) for h in legacy.segment_hits(Q, 1.0)}
    assert hb == hl
    assert batched.eval_count == legacy.eval_count
    assert batched.dispatch_count < legacy.dispatch_count
    # end-to-end query type I agrees too
    assert batched.query_range(Q, 1.0) == legacy.query_range(Q, 1.0)


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_counter_backend_parity(backend):
    """jax / pallas backends produce the numpy backend's distances."""
    data = _strings(24, l=8)
    dist = get("levenshtein")
    ref = CountedDistance(dist, data, backend="numpy")
    alt = CountedDistance(dist, data, backend=backend)
    q = data[0]
    idxs = np.arange(len(data))
    np.testing.assert_allclose(ref.eval(q, idxs), alt.eval(q, idxs),
                               rtol=1e-4, atol=1e-4)
    # rectangular (q shorter than windows) bucket
    np.testing.assert_allclose(ref.eval(q[:6], idxs), alt.eval(q[:6], idxs),
                               rtol=1e-4, atol=1e-4)
    assert alt.dispatches == 2 and alt.count == 2 * len(data)


@pytest.mark.parametrize("name", ["levenshtein", "erp"])
def test_np_backend_matrix_parity(name):
    """np_backend.matrix_for matches the registry's jitted Distance.matrix."""
    from repro.distances import np_backend
    dist = get(name)
    rng = np.random.default_rng(5)
    if dist.string:
        xs = rng.integers(0, 6, size=(5, 7))
        ys = rng.integers(0, 6, size=(4, 7))
    else:
        xs = rng.normal(size=(5, 7, 2)).astype(np.float32)
        ys = rng.normal(size=(4, 7, 2)).astype(np.float32)
    got = np_backend.matrix_for(name)(xs, ys)
    want = np.asarray(dist.matrix(xs, ys))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # ragged lengths (padded rows) must agree with per-pair evaluation
    lx = np.array([7, 5, 6, 7, 4])
    ly = np.array([3, 7, 6, 5])
    got = np_backend.matrix_for(name)(xs, ys, lx, ly)
    batch = np_backend.batch_for(name)
    for i in range(5):
        for j in range(4):
            want_ij = batch(xs[i:i + 1], ys[j:j + 1],
                            lx[i:i + 1], ly[j:j + 1])[0]
            np.testing.assert_allclose(got[i, j], want_ij,
                                       rtol=1e-4, atol=1e-4)


def test_linear_scan_engine_single_round():
    """A linear-scan bucket is exactly one dispatch for ALL segments."""
    data = _strings(64)
    idx = LinearScanIndex(get("levenshtein"), data).build()
    queries = data[:7]
    idx.counter.reset()
    engine = BatchEngine(idx.counter)
    engine.run([idx.range_query_plan(2.0) for _ in queries], queries, 2.0)
    assert engine.rounds == 1
    assert idx.counter.dispatches == 1
    assert idx.counter.count == 7 * len(data)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1.0, 2.0, 4.0]))
    def test_engine_parity_property(seed, eps):
        rng = np.random.default_rng(seed)
        data = _strings(60, rng=rng)
        net = ReferenceNet(get("levenshtein"), data, eps_prime=1.0).build()
        queries = data[rng.integers(0, len(data), 4)]
        net.counter.reset()
        host = [net.range_query(q, eps) for q in queries]
        hc = net.counter.count
        net.counter.reset()
        eng = BatchEngine(net.counter).run(
            [net.range_query_plan(eps) for _ in queries], queries, eps)
        assert eng == host and net.counter.count == hc
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install .[dev])")
    def test_engine_parity_property():
        pass
