"""Elastic fleet serving on the batched substrate: round-based
(shared-frontier) and one-shot stacked serving vs host-loop hit parity
across the metric distances, the round path's eval-count parity property,
incremental resize parity vs full rebuild under worker add/remove/kill,
and the ``{query, build}`` accounting buckets across
``__init__``/``resize``."""

import numpy as np
import pytest

from repro.data.synthetic import proteins, trajectories
from repro.launch.elastic import ElasticIndex

#: the four metric distances the indexed path supports (dtw is excluded by
#: require_metric, exactly as in the build/bulk suites)
CASES = [
    ("levenshtein", proteins, 1.0, 2.0),
    ("erp", trajectories, 0.5, 1.0),
    ("frechet", trajectories, 0.25, 0.6),
    ("euclidean", trajectories, 0.5, 1.5),
]


def _fleet(dist_name, gen, eps_prime, n=120, workers=("a", "b", "c"),
           seed=7):
    data = gen(n, seed=seed)
    return data, ElasticIndex(dist_name, data, list(workers),
                              eps_prime=eps_prime)


@pytest.mark.parametrize("dist_name,gen,eps_prime,eps", CASES)
def test_batched_serving_matches_host_loop(dist_name, gen, eps_prime, eps):
    """Acceptance: both batched serving modes — round-based shared
    frontier (the default) and the legacy one-shot stacked device query —
    return hit sets identical to the host per-shard pointer-chasing
    loop."""
    data, fleet = _fleet(dist_name, gen, eps_prime)
    qs = data[[3, 40, 77]]
    want = [fleet.range_query(q, eps, batched=False) for q in qs]
    assert fleet.range_query_batch(qs, eps, mode="rounds") == want
    assert fleet.range_query_batch(qs, eps, mode="oneshot") == want
    # the single-query wrapper takes the default (rounds) path
    assert fleet.range_query(qs[0], eps) == want[0]
    # batched runs are device work, not host-counter work
    assert fleet.device_stats["device_queries"] > 0
    assert fleet.device_stats["total_evals"] > 0
    assert fleet.device_stats["rounds"] > 0


@pytest.mark.parametrize("dist_name,gen,eps_prime,eps", CASES)
def test_round_serving_eval_parity_with_host_loop(dist_name, gen,
                                                  eps_prime, eps):
    """The round-based path's consistency property: it drives the SAME
    Alg.-3 frontier plans as the host per-shard loop, so hit sets AND
    exact-evaluation counts are identical — the device path merely merges
    who evaluates a round.  Holds under dead-worker masking and after a
    resize; the build bucket is untouched by serving on either path."""
    data, fleet = _fleet(dist_name, gen, eps_prime)
    # ragged query lengths ride the packed dispatch; equal-length-only
    # distances (euclidean) keep the full window width
    widths = (None, None, None) if dist_name == "euclidean" \
        else (None, -1, -2)
    qs = [data[i][:w] for i, w in zip((3, 40, 77), widths)]

    def check(dead=()):
        build0 = fleet.eval_count()["build"]
        host0 = fleet.eval_count()["query"]
        want = [fleet.range_query(q, eps, dead=dead, batched=False)
                for q in qs]
        host_evals = fleet.eval_count()["query"] - host0
        dev0 = fleet.device_stats["total_evals"]
        got = fleet.range_query_batch(qs, eps, dead=dead, mode="rounds")
        dev_evals = fleet.device_stats["total_evals"] - dev0
        assert got == want
        assert dev_evals == host_evals
        assert fleet.eval_count()["query"] == host0 + host_evals
        assert fleet.eval_count()["build"] == build0

    check()
    check(dead=("b",))
    fleet.resize(["a", "c", "d"])
    check()


@pytest.mark.parametrize("dist_name,gen,eps_prime,eps", CASES[:2])
def test_resize_parity_vs_full_rebuild(dist_name, gen, eps_prime, eps):
    """Worker add (survivors shrink), remove (survivors grow), and a
    round-trip must all serve exactly what a freshly built fleet serves —
    on both the stacked and the host path."""
    data, fleet = _fleet(dist_name, gen, eps_prime, n=150)
    qs = data[[5, 60, 110]]
    want = [fleet.range_query(q, eps, batched=False) for q in qs]

    for new_workers in (["a", "b", "c", "d"],   # add: shards shed windows
                        ["a", "c", "d"],        # swap: shed + gain
                        ["a", "c"]):            # remove: survivors gain
        frac = fleet.resize(new_workers)
        assert 0.0 < frac < 1.0
        fresh = ElasticIndex(dist_name, data, new_workers,
                             eps_prime=eps_prime)
        got_stacked = fleet.range_query_batch(qs, eps)
        got_loop = [fleet.range_query(q, eps, batched=False) for q in qs]
        got_fresh = [fresh.range_query(q, eps, batched=False) for q in qs]
        assert got_stacked == want
        assert got_loop == want
        assert got_fresh == want


def test_dead_worker_masking_degrades_to_survivor_union():
    """`dead=` maps onto the stacked fleet query's dead-shard mask: the
    answer is the exact union of the surviving shards' partitions, on both
    paths, and a subsequent resize restores the full answer."""
    data, fleet = _fleet("levenshtein", proteins, 1.0, n=150)
    qs = data[[4, 90]]
    full = fleet.range_query_batch(qs, 2.0)
    dead_gids = set(fleet.assignment["b"])
    for q, want_full in zip(qs, full):
        expect = sorted(set(want_full) - dead_gids)
        assert fleet.range_query(q, 2.0, dead=("b",)) == expect
        assert fleet.range_query(q, 2.0, dead=("b",),
                                 batched=False) == expect
    # the kill path: resize the dead worker away, exactness returns
    fleet.resize(["a", "c"])
    assert fleet.range_query_batch(qs, 2.0) == full


def test_eval_count_buckets_across_init_and_resize():
    """The PR-3 accounting bugfix: construction and reshard cost lives in
    the ``build`` bucket (previously read from the query counter and
    silently reported 0 after PR 2), host queries in ``query``, and both
    buckets are monotone across resizes even when shards are retired."""
    data = proteins(120, seed=9)
    fleet = ElasticIndex("levenshtein", data, ["a", "b"])
    ec0 = fleet.eval_count()
    assert ec0["build"] > 0 and ec0["query"] == 0

    # device serving touches neither host bucket
    fleet.range_query(data[0], 2.0)
    assert fleet.eval_count() == ec0
    assert fleet.device_stats["device_queries"] == 1

    # host serving lands in the query bucket only
    fleet.range_query(data[0], 2.0, batched=False)
    ec1 = fleet.eval_count()
    assert ec1["query"] > 0 and ec1["build"] == ec0["build"]

    # resize cost lands in the build bucket only (the old bug: 0)
    fleet.resize(["a", "b", "c"])
    ec2 = fleet.eval_count()
    assert ec2["build"] > ec1["build"]
    assert ec2["query"] == ec1["query"]

    # dropping a worker retires its counter without losing its history
    fleet.resize(["a", "c"])
    ec3 = fleet.eval_count()
    assert ec3["build"] >= ec2["build"]
    assert ec3["query"] == ec2["query"]


def test_resize_is_incremental_not_full_rebuild():
    """An N->N+1 resize must cost a fraction of the original build, not a
    second full build (the bench gates 2/N at scale; the bound here is
    looser because tiny shards amortise worse)."""
    data = proteins(240, seed=11)
    fleet = ElasticIndex("levenshtein", data, ["a", "b", "c"])
    full_build = fleet.eval_count()["build"]
    fleet.resize(["a", "b", "c", "d"])
    spent = fleet.eval_count()["build"] - full_build
    assert 0 < spent < full_build, (spent, full_build)


def test_backend_selection_builds_identical_shards():
    """Shard construction accepts any CountedDistance backend; numpy and
    jax cohort builds serve identical hit sets."""
    data = proteins(90, seed=13)
    hits = []
    for backend in ("numpy", "jax"):
        fleet = ElasticIndex("levenshtein", data, ["a", "b"],
                             backend=backend)
        assert all(s.net.counter.backend == backend
                   for s in fleet.shards.values() if s)
        hits.append([fleet.range_query(q, 2.0, batched=False)
                     for q in data[[2, 50]]])
    assert hits[0] == hits[1]
