"""Device-mode retrieval (flattened net + static-capacity compaction) and
the fleet query: exactness vs brute force, pruning accounting, overflow
retry, embedding retrieval integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import (FlatNet, device_range_query, flatten_net,
                                    fleet_range_query, host_reference_hits,
                                    merge_flats)
from repro.core.refnet import ReferenceNet
from repro.data.synthetic import proteins, trajectories
from repro.distances import get

RNG = np.random.default_rng(31)


def _net(data, dist_name, eps_prime):
    return ReferenceNet(get(dist_name), data, eps_prime=eps_prime,
                        tight_bounds=True).build()


@pytest.mark.parametrize("dist_name,gen,eps_prime,eps", [
    ("levenshtein", proteins, 1.0, 2.0),
    ("erp", trajectories, 0.5, 1.0),
])
def test_device_query_matches_brute_force(dist_name, gen, eps_prime, eps):
    data = gen(160, seed=8)
    flat = flatten_net(_net(data, dist_name, eps_prime))
    qs = data[RNG.integers(0, len(data), 6)].copy()
    hits, stats = device_range_query(flat, qs, eps)
    want = host_reference_hits(flat, qs, eps)
    np.testing.assert_array_equal(hits, want)
    assert stats["total_evals"] > 0


def test_device_query_prunes():
    data = proteins(400, seed=9)
    flat = flatten_net(_net(data, "levenshtein", 1.0))
    qs = data[:4]
    _, stats = device_range_query(flat, qs, eps=1.0)
    naive = 4 * len(data)
    assert stats["total_evals"] < 0.8 * naive, stats


def test_capacity_overflow_retry():
    data = proteins(120, seed=10)
    flat = flatten_net(_net(data, "levenshtein", 1.0))
    qs = data[:3]
    hits, stats = device_range_query(flat, qs, eps=6.0, capacity=8)
    want = host_reference_hits(flat, qs, 6.0)
    np.testing.assert_array_equal(hits, want)
    assert stats["capacity"] > 8  # ladder kicked in


def test_fleet_union_is_exact_and_survives_dead_shard():
    data = proteins(300, seed=11)
    thirds = np.array_split(np.arange(len(data)), 3)
    flats = [flatten_net(_net(data[ix], "levenshtein", 1.0))
             for ix in thirds]
    qs = data[:3]
    res, _ = fleet_range_query(flats, qs, eps=2.0)
    got = np.zeros((3, len(data)), bool)
    for ix, h in zip(thirds, res):
        got[:, ix] = h
    flat_all = FlatNet  # brute force on the union
    want = host_reference_hits(
        flatten_net(_net(data, "levenshtein", 1.0)), qs, 2.0)
    np.testing.assert_array_equal(got, want)
    # dead shard: remaining shards still exact on their partitions
    res2, _ = fleet_range_query(flats, qs, eps=2.0, dead=(1,))
    assert res2[1] is None
    np.testing.assert_array_equal(res2[0], res[0])
    np.testing.assert_array_equal(res2[2], res[2])


def test_fleet_stacked_matches_per_shard_loop():
    """The stacked fleet path (merge_flats + one device query) returns the
    exact per-shard masks of the sequential host-Python loop."""
    data = proteins(240, seed=12)
    thirds = np.array_split(np.arange(len(data)), 3)
    flats = [flatten_net(_net(data[ix], "levenshtein", 1.0))
             for ix in thirds]
    qs = data[:4]
    stacked, st_stats = fleet_range_query(flats, qs, eps=2.0, stacked=True)
    looped, _ = fleet_range_query(flats, qs, eps=2.0, stacked=False)
    for s, l in zip(stacked, looped):
        np.testing.assert_array_equal(s, l)
    assert st_stats[0].get("merged") and st_stats[0]["n_shards"] == 3
    assert st_stats[0]["fleet_total_evals"] > 0
    assert st_stats[0] is not st_stats[1]  # independent per-shard dicts
    # merged width pads every shard's member lists to the fleet maximum
    merged, offsets = merge_flats(flats)
    assert merged.eval_width == max(f.eval_width for f in flats)
    assert offsets == [0, len(flats[0].data),
                       len(flats[0].data) + len(flats[1].data)]


def test_flatten_reuses_stored_link_distances():
    """flatten_net takes direct pivot->child distances from the net instead
    of re-evaluating them: the flatten dispatch spends strictly fewer
    evaluations than the total member count, and the distances match a
    direct computation."""
    from repro.distances import np_backend
    data = proteins(200, seed=13)
    net = _net(data, "levenshtein", 1.0)
    before = net.counter.build_count
    flat = flatten_net(net)
    spent = net.counter.build_count - before
    n_members = int((flat.members >= 0).sum())
    assert spent < n_members, (spent, n_members)
    batch = np_backend.batch_for("levenshtein")
    for i in range(flat.n_pivots):
        ms = flat.members[i][flat.members[i] >= 0]
        if ms.size == 0:
            continue
        pid = int(flat.pivot_ids[i])
        want = np.asarray(batch(
            np.repeat(data[pid][None], ms.size, 0), data[ms]))
        np.testing.assert_allclose(flat.member_dist[i, :ms.size], want,
                                   rtol=1e-4, atol=1e-4)


def test_flatnet_append_stays_exact():
    """Incremental append: fresh windows attached to existing pivots keep
    device queries exact without re-flattening."""
    from repro.distances import np_backend
    data = proteins(170, seed=14)
    base, new = data[:150], data[150:]
    flat = flatten_net(_net(base, "levenshtein", 1.0))
    batch = np_backend.batch_for("levenshtein")
    rows, ids, dists = [], [], []
    for k, w in enumerate(new):
        ds = np.asarray(batch(
            np.repeat(w[None], flat.n_pivots, 0), flat.pivots))
        p = int(np.argmin(ds))
        rows.append(p)
        ids.append(150 + k)
        dists.append(float(ds[p]))
    old_width = flat.eval_width
    flat.append(rows, ids, dists, new_data=new)
    assert len(flat.data) == len(data)
    assert flat.eval_width >= old_width
    qs = data[:5]
    hits, _ = device_range_query(flat, qs, eps=2.0)
    np.testing.assert_array_equal(hits, host_reference_hits(flat, qs, 2.0))


def _toy_flat():
    """A 1-pivot FlatNet whose FIRST member slot needs an exact eval at
    eps=2.5: q=(0,0), pivot=(3,0) (d=3, radius 2 -> undecided), member 0 is
    window 1 at link distance 1 (ring bound [2,4] straddles eps)."""
    data = np.asarray([[3.0, 0.0], [4.0, 0.0], [1.0, 0.0]], np.float32)
    return FlatNet(
        pivots=data[[0]], pivot_radius=np.asarray([2.0], np.float32),
        members=np.asarray([[1, 0, 2]], np.int64),
        member_dist=np.asarray([[1.0, 0.0, 2.0]], np.float32),
        data=data, n_pivots=1, dist_name="euclidean",
        pivot_ids=np.asarray([0], np.int64))


def test_member_eval_stats_not_inflated_by_padding():
    """Regression (PR 3): jnp.nonzero pads the survivor compaction with
    index 0; when slot 0 genuinely needs evaluation the padding aliased it
    and every padding row was counted as a member eval.  Validity is now
    positional, so the stats report exactly the undecided survivors."""
    flat = _toy_flat()
    qs = np.zeros((1, 2), np.float32)
    hits, stats = device_range_query(flat, qs, eps=2.5, capacity=16)
    # slots: (w=1, ring [2,4]) -> eval; (w=0, lo=3) -> pruned free;
    # (w=2, ring [1,5]) -> eval.  Padding must not count.
    assert stats["member_evals"] == 2, stats
    assert stats["total_evals"] == flat.n_pivots + 2
    np.testing.assert_array_equal(hits, [[False, False, True]])


def test_fleet_stats_parity_stacked_vs_loop_with_undecided_slot0():
    """With the positional-validity fix, the merged fleet query's member
    evals equal the sum of the per-shard loop's — even when the merged
    net's survivor slot 0 is undecided (the aliasing trigger)."""
    shard2_data = np.asarray([[6.0, 0.0], [5.0, 0.0]], np.float32)
    shard2 = FlatNet(
        pivots=shard2_data[[0]], pivot_radius=np.asarray([1.0], np.float32),
        members=np.asarray([[1, 0]], np.int64),
        member_dist=np.asarray([[1.0, 0.0]], np.float32),
        data=shard2_data, n_pivots=1, dist_name="euclidean",
        pivot_ids=np.asarray([0], np.int64))
    flats = [_toy_flat(), shard2]
    qs = np.zeros((1, 2), np.float32)
    stacked, st = fleet_range_query(flats, qs, eps=2.5, stacked=True)
    looped, lp = fleet_range_query(flats, qs, eps=2.5, stacked=False)
    for s, l in zip(stacked, looped):
        np.testing.assert_array_equal(s, l)
    assert st[0]["fleet_member_evals"] == sum(x["member_evals"] for x in lp)
    assert st[0]["fleet_total_evals"] == sum(x["total_evals"] for x in lp)


def test_merge_flats_preserves_pivot_ids_with_offsets():
    """Regression (PR 3): merge_flats dropped pivot_ids, so a merged net
    could never be refreshed with FlatNet.append.  They now concatenate
    with each shard's data offset applied, and post-merge appends keep
    device queries exact."""
    data = proteins(120, seed=21)
    halves = np.array_split(np.arange(len(data)), 2)
    flats = [flatten_net(_net(data[ix], "levenshtein", 1.0))
             for ix in halves]
    merged, offsets = merge_flats(flats)
    want = np.concatenate([np.asarray(f.pivot_ids) + off
                           for f, off in zip(flats, offsets)])
    np.testing.assert_array_equal(merged.pivot_ids, want)
    # post-merge append: attach a fresh window to pivot row 0 of shard 1
    from repro.distances import np_backend
    batch = np_backend.batch_for("levenshtein")
    new = proteins(121, seed=22)[-1:]
    prow = flats[0].n_pivots        # shard 1's first pivot row in the merge
    pid = int(merged.pivot_ids[prow])
    d = float(np.asarray(batch(new, merged.data[pid][None]))[0])
    merged.append([prow], [len(merged.data)], [d], new_data=new)
    qs = data[:3]
    hits, _ = device_range_query(merged, qs, eps=2.0)
    np.testing.assert_array_equal(hits, host_reference_hits(merged, qs, 2.0))


def test_flatnet_remove_masks_members_and_keeps_append_exact():
    """FlatNet.remove masks departed windows with zero evaluations (rows
    re-compacted so later appends never overwrite live members), and the
    shrunken net keeps serving exactly."""
    from repro.distances import np_backend
    data = proteins(140, seed=23)
    flat = flatten_net(_net(data, "levenshtein", 1.0))
    removed = [3, 10, 11, 57]
    flat.remove(removed)
    live = np.setdiff1d(flat.members[flat.members >= 0], [])
    assert not set(removed) & set(live.tolist())
    qs = data[:4]
    hits, _ = device_range_query(flat, qs, eps=2.0)
    want = host_reference_hits(flat, qs, 2.0)
    want[:, removed] = False        # departed windows are never hits
    np.testing.assert_array_equal(hits, want)
    # append after remove: the compacted rows accept new members cleanly
    batch = np_backend.batch_for("levenshtein")
    new = proteins(141, seed=24)[-1:]
    ds = np.asarray(batch(np.repeat(new, flat.n_pivots, 0), flat.pivots))
    p = int(np.argmin(ds))
    flat.append([p], [len(flat.data)], [float(ds[p])], new_data=new)
    hits2, _ = device_range_query(flat, qs, eps=2.0)
    want2 = host_reference_hits(flat, qs, 2.0)
    want2[:, removed] = False
    np.testing.assert_array_equal(hits2, want2)


def test_matcher_flat_net_cache_respects_pivot_level():
    from repro.core.matching import SubsequenceMatcher
    rng = np.random.default_rng(15)
    seqs = [rng.integers(0, 8, size=(60,)) for _ in range(3)]
    m = SubsequenceMatcher("levenshtein", 8, 1, index="refnet",
                           tight_bounds=True).build(seqs)
    default = m.flat_net()
    assert m.flat_net() is default            # same level -> cached
    lvl = 1
    explicit = m.flat_net(pivot_level=lvl)
    assert m.flat_net(pivot_level=lvl) is explicit
    back = m.flat_net()                       # default again -> re-flatten
    assert back is not explicit
    qs = m.windows[:3]
    for flat in (explicit, back):
        hits, _ = device_range_query(flat, qs, eps=1.0)
        np.testing.assert_array_equal(
            hits, host_reference_hits(flat, qs, 1.0))


def test_embedding_retrieval_end_to_end():
    from repro.core.embedding_retrieval import EmbeddingRetriever, embed_windows
    from repro.models import registry
    from repro.models.params import init_params

    cfg, mod = registry.get("smollm-360m", reduced=True)
    params = init_params(mod.param_defs(cfg), jax.random.PRNGKey(4),
                         jnp.float32)
    rng = np.random.default_rng(6)
    seqs = [rng.integers(0, cfg.vocab, size=(48,)) for _ in range(4)]
    seqs.append(seqs[0].copy())  # a duplicate sequence
    vecs, meta = embed_windows(mod, params, cfg, seqs, window=8)
    ret = EmbeddingRetriever(vecs, meta, eps_prime=0.02)
    # a window of the duplicate sequence retrieves its twin at distance ~0
    probe_i = next(i for i, m in enumerate(meta) if m.seq_id == 4)
    got = ret.query(vecs[probe_i], eps=1e-4)
    seq_ids = {m.seq_id for m, _ in got}
    assert {0, 4} <= seq_ids
    near = ret.nearest(vecs[probe_i])
    assert near is not None and near[1] <= 1e-4
