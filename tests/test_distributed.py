"""Device-mode retrieval (flattened net + static-capacity compaction) and
the fleet query: exactness vs brute force, pruning accounting, overflow
retry, embedding retrieval integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import (FlatNet, device_range_query, flatten_net,
                                    fleet_range_query, host_reference_hits)
from repro.core.refnet import ReferenceNet
from repro.data.synthetic import proteins, trajectories
from repro.distances import get

RNG = np.random.default_rng(31)


def _net(data, dist_name, eps_prime):
    return ReferenceNet(get(dist_name), data, eps_prime=eps_prime,
                        tight_bounds=True).build()


@pytest.mark.parametrize("dist_name,gen,eps_prime,eps", [
    ("levenshtein", proteins, 1.0, 2.0),
    ("erp", trajectories, 0.5, 1.0),
])
def test_device_query_matches_brute_force(dist_name, gen, eps_prime, eps):
    data = gen(160, seed=8)
    flat = flatten_net(_net(data, dist_name, eps_prime))
    qs = data[RNG.integers(0, len(data), 6)].copy()
    hits, stats = device_range_query(flat, qs, eps)
    want = host_reference_hits(flat, qs, eps)
    np.testing.assert_array_equal(hits, want)
    assert stats["total_evals"] > 0


def test_device_query_prunes():
    data = proteins(400, seed=9)
    flat = flatten_net(_net(data, "levenshtein", 1.0))
    qs = data[:4]
    _, stats = device_range_query(flat, qs, eps=1.0)
    naive = 4 * len(data)
    assert stats["total_evals"] < 0.8 * naive, stats


def test_capacity_overflow_retry():
    data = proteins(120, seed=10)
    flat = flatten_net(_net(data, "levenshtein", 1.0))
    qs = data[:3]
    hits, stats = device_range_query(flat, qs, eps=6.0, capacity=8)
    want = host_reference_hits(flat, qs, 6.0)
    np.testing.assert_array_equal(hits, want)
    assert stats["capacity"] > 8  # ladder kicked in


def test_fleet_union_is_exact_and_survives_dead_shard():
    data = proteins(300, seed=11)
    thirds = np.array_split(np.arange(len(data)), 3)
    flats = [flatten_net(_net(data[ix], "levenshtein", 1.0))
             for ix in thirds]
    qs = data[:3]
    res, _ = fleet_range_query(flats, qs, eps=2.0)
    got = np.zeros((3, len(data)), bool)
    for ix, h in zip(thirds, res):
        got[:, ix] = h
    flat_all = FlatNet  # brute force on the union
    want = host_reference_hits(
        flatten_net(_net(data, "levenshtein", 1.0)), qs, 2.0)
    np.testing.assert_array_equal(got, want)
    # dead shard: remaining shards still exact on their partitions
    res2, _ = fleet_range_query(flats, qs, eps=2.0, dead=(1,))
    assert res2[1] is None
    np.testing.assert_array_equal(res2[0], res[0])
    np.testing.assert_array_equal(res2[2], res[2])


def test_embedding_retrieval_end_to_end():
    from repro.core.embedding_retrieval import EmbeddingRetriever, embed_windows
    from repro.models import registry
    from repro.models.params import init_params

    cfg, mod = registry.get("smollm-360m", reduced=True)
    params = init_params(mod.param_defs(cfg), jax.random.PRNGKey(4),
                         jnp.float32)
    rng = np.random.default_rng(6)
    seqs = [rng.integers(0, cfg.vocab, size=(48,)) for _ in range(4)]
    seqs.append(seqs[0].copy())  # a duplicate sequence
    vecs, meta = embed_windows(mod, params, cfg, seqs, window=8)
    ret = EmbeddingRetriever(vecs, meta, eps_prime=0.02)
    # a window of the duplicate sequence retrieves its twin at distance ~0
    probe_i = next(i for i, m in enumerate(meta) if m.seq_id == 4)
    got = ret.query(vecs[probe_i], eps=1e-4)
    seq_ids = {m.seq_id for m, _ in got}
    assert {0, 4} <= seq_ids
    near = ret.nearest(vecs[probe_i])
    assert near is not None and near[1] <= 1e-4
