"""Bulk construction pipeline: cohort-batched insert plans produce nets
that pass the structural invariants and return the exact same range-query
hit sets as sequentially built nets, across all four metric distances,
with a hard bound on the dispatch collapse; plus deletion re-homing after
a bulk build, stacked MV construction, and build-bucket accounting."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.counter import CountedDistance
from repro.core.covertree import CoverTree
from repro.core.refindex import MVReferenceIndex
from repro.core.refnet import ReferenceNet
from repro.distances import get

RNG = np.random.default_rng(23)


def _strings(n, l=10, alphabet=16, rng=RNG):
    motifs = rng.integers(0, alphabet, size=(10, l))
    data = motifs[rng.integers(0, 10, n)]
    m = rng.random((n, l)) < 0.15
    return np.where(m, rng.integers(0, alphabet, size=(n, l)), data)


def _series(n, l=10, rng=RNG):
    steps = rng.normal(scale=0.3, size=(n, l, 2))
    return np.cumsum(steps, axis=1) + rng.normal(scale=2.0, size=(n, 1, 2))


# the four metric distances the indexed path supports (dtw is excluded by
# require_metric; euclidean exercises the fixed-length, non-wavefront path)
METRIC_CASES = [
    ("levenshtein", _strings, 1.0, [1.0, 3.0]),
    ("erp", _series, 0.5, [0.5, 1.5]),
    ("frechet", _series, 0.25, [0.25, 0.75]),
    ("euclidean", _series, 0.5, [0.5, 1.5]),
]


@pytest.mark.parametrize("dist_name,gen,eps_prime,ranges", METRIC_CASES)
@pytest.mark.parametrize("kw", [{}, dict(num_max=4, tight_bounds=True)])
def test_build_batched_invariants_and_hit_parity(dist_name, gen, eps_prime,
                                                 ranges, kw):
    """The acceptance property: a bulk-built net is a valid reference net
    and answers every range query with the same hit set as the
    sequentially built one (structures may differ; answers may not)."""
    data = gen(200)
    dist = get(dist_name)
    seq = ReferenceNet(dist, data, eps_prime=eps_prime, **kw).build()
    bat = ReferenceNet(dist, data, eps_prime=eps_prime, **kw).build_batched()
    bat.check_invariants()
    for eps in ranges:
        for qi in (3, 77, 140):
            q = data[qi]
            assert bat.range_query(q, eps) == seq.range_query(q, eps)


@pytest.mark.parametrize("dist_name,gen,eps_prime,ranges", METRIC_CASES[:2])
def test_covertree_build_batched(dist_name, gen, eps_prime, ranges):
    data = gen(150)
    dist = get(dist_name)
    ct = CoverTree(dist, data, eps_prime=eps_prime).build_batched()
    ct.check_invariants()  # includes the single-parent assertion
    naive = CountedDistance(dist, data)
    eps = ranges[-1]
    q = data[5]
    want = sorted(np.nonzero(
        naive.eval(q, np.arange(len(data))) <= eps)[0].tolist())
    assert ct.range_query(q, eps) == want


def test_build_dispatch_collapse():
    """Regression bound on the tentpole win: cohort batching must collapse
    construction dispatches by well over the arbitration overhead."""
    data = _strings(300)
    dist = get("levenshtein")
    seq = ReferenceNet(dist, data, eps_prime=1.0).build()
    bat = ReferenceNet(dist, data, eps_prime=1.0).build_batched()
    assert bat.counter.build_count > 0
    assert seq.counter.build_dispatches >= 5 * bat.counter.build_dispatches, (
        seq.counter.build_dispatches, bat.counter.build_dispatches)


def test_build_charges_build_bucket_only():
    """Construction must never pollute the paper's query-time currency."""
    data = _strings(120)
    net = ReferenceNet(get("levenshtein"), data, eps_prime=1.0).build_batched()
    assert net.counter.count == 0 and net.counter.dispatches == 0
    assert net.counter.build_count > 0 and net.counter.build_dispatches > 0
    net.range_query(data[0], 2.0)
    assert net.counter.count > 0  # queries land in the query bucket


def test_insert_counts_match_plan_driven_path():
    """insert() is the sequential drive of insert_plan: the root insert is
    free, and the second insert spends exactly one evaluation in one
    dispatch (the root probe; no deeper level has undiscovered candidates),
    exactly as the historical pair-at-a-time descent did."""
    data = _strings(60)
    net = ReferenceNet(get("levenshtein"), data, eps_prime=1.0)
    net.insert(0)
    assert net.counter.build_count == 0  # root insert is free
    net.insert(1)
    assert net.counter.build_count == 1
    assert net.counter.build_dispatches == 1


def test_delete_after_bulk_build_rehomes():
    """Alg. 2 deletion on a bulk-built net: orphaned members re-insert and
    queries stay exact (previously untested on any net)."""
    data = _strings(150)
    dist = get("levenshtein")
    net = ReferenceNet(dist, data, eps_prime=1.0).build_batched()
    naive = CountedDistance(dist, data)
    # drop several references (nodes with children re-home their lists)
    refs = [n.idx for n in net.nodes.values()
            if n.idx != net.root and n.children][:4]
    plain = [n.idx for n in net.nodes.values()
             if n.idx != net.root and not n.children][:2]
    drop = refs + plain
    for i in drop:
        net.delete(i)
    assert all(i not in net.nodes for i in drop)
    for n in net.nodes.values():  # every survivor is still homed
        if n.idx != net.root:
            assert n.parents
    keep = np.array([i for i in range(len(data)) if i not in drop])
    for eps in (1.0, 2.0):
        q = data[int(keep[7])]
        want = sorted(int(i) for i in keep[naive.eval(q, keep) <= eps])
        assert net.range_query(q, eps) == want


def test_bulk_build_is_incremental():
    """build_batched on a partially built net only inserts the remainder."""
    data = _strings(100)
    dist = get("levenshtein")
    net = ReferenceNet(dist, data, eps_prime=1.0)
    for i in range(10):
        net.insert(i)
    net.build_batched()
    assert len(net.nodes) == len(data)
    net.check_invariants()


def test_mv_stacked_build_matches_direct_table():
    from repro.distances import np_backend
    data = _strings(140)
    dist = get("levenshtein")
    mv = MVReferenceIndex(dist, data, n_refs=5).build()
    # construction charged to the build bucket, in very few dispatches
    assert mv.counter.count == 0 and mv.counter.dispatches == 0
    assert mv.counter.build_dispatches <= 4
    batch = np_backend.batch_for("levenshtein")
    for k, r in enumerate(mv.refs):
        ds = np.asarray(batch(np.repeat(data[r][None], len(data), 0), data))
        np.testing.assert_allclose(mv.table[k], ds, rtol=1e-5, atol=1e-5)
    naive = CountedDistance(dist, data)
    q = data[7]
    want = sorted(np.nonzero(
        naive.eval(q, np.arange(len(data))) <= 3.0)[0].tolist())
    assert mv.range_query(q, 3.0) == want


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1.0, 2.0, 4.0]))
    def test_bulk_parity_property(seed, eps):
        rng = np.random.default_rng(seed)
        data = _strings(80, rng=rng)
        dist = get("levenshtein")
        seq = ReferenceNet(dist, data, eps_prime=1.0).build()
        bat = ReferenceNet(dist, data, eps_prime=1.0).build_batched()
        bat.check_invariants()
        for q in data[rng.integers(0, len(data), 3)]:
            assert bat.range_query(q, eps) == seq.range_query(q, eps)
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install .[dev])")
    def test_bulk_parity_property():
        pass
