"""Continuous-batching serve subsystem (PR 9): fleet snapshot/restore
round-trips (array + accounting + hit-set parity, restore-then-resize on
both the shrink and the append/grow paths), the request queue, the serve
engine's shared-round admission (continuous vs sequential dispatch
counts, tick vs greedy, the in-flight cap, latency accounting), the
zero-downtime mid-load snapshot-swap resize, wall-clock serving on a
thread with the open-loop Poisson load generator, and the
config/facade wiring (`serve_*` fields, `Retriever.serve()`)."""

import threading

import numpy as np
import pytest

from repro.data.synthetic import proteins, trajectories
from repro.launch.elastic import ElasticIndex
from repro.serve import (FleetSnapshotManager, OpenLoopLoadGen,
                         RequestQueue, ServeConfig, ServeEngine,
                         poisson_schedule)

CASES = [
    ("levenshtein", proteins, 1.0, 2.0),
    ("erp", trajectories, 0.5, 1.0),
]


def _fleet(dist_name="levenshtein", gen=proteins, eps_prime=1.0, n=120,
           workers=("a", "b", "c"), seed=7, **kw):
    data = gen(n, seed=seed)
    return data, ElasticIndex(dist_name, data, list(workers),
                              eps_prime=eps_prime, **kw)


def _oracle(fleet, qs, eps):
    return [fleet.range_query(q, eps, batched=False) for q in qs]


# -- snapshot / restore ------------------------------------------------------


@pytest.mark.parametrize("dist_name,gen,eps_prime,eps", CASES)
def test_snapshot_round_trip_arrays_and_hits(tmp_path, dist_name, gen,
                                             eps_prime, eps):
    """Restore rebuilds every shard bit-for-bit — FlatNet arrays,
    envelopes, gids, pivot ids — spends ZERO distance evaluations, and
    the clone answers exactly like the original."""
    data, fleet = _fleet(dist_name, gen, eps_prime)
    qs = data[[3, 40, 77]]
    want = _oracle(fleet, qs, eps)
    counts = fleet.eval_count()

    snap = FleetSnapshotManager(tmp_path)
    step = snap.save(fleet, block=True)
    clone = snap.restore(step)

    # restore is pure I/O: the {query, build} buckets carry over exactly
    assert clone.eval_count() == counts
    assert fleet.eval_count() == counts
    assert clone.workers == fleet.workers
    for w in fleet.workers:
        s, c = fleet.shards[w], clone.shards[w]
        np.testing.assert_array_equal(s.gids, c.gids)
        np.testing.assert_array_equal(s.flat.pivots, c.flat.pivots)
        np.testing.assert_array_equal(s.flat.pivot_radius,
                                      c.flat.pivot_radius)
        np.testing.assert_array_equal(s.flat.members, c.flat.members)
        np.testing.assert_array_equal(s.flat.member_dist,
                                      c.flat.member_dist)
        np.testing.assert_array_equal(s.flat.pivot_ids, c.flat.pivot_ids)
        if s.flat.envelopes is not None:
            np.testing.assert_array_equal(s.flat.envelopes.lo,
                                          c.flat.envelopes.lo)
            np.testing.assert_array_equal(s.flat.envelopes.hi,
                                          c.flat.envelopes.hi)
            np.testing.assert_array_equal(s.flat.envelopes.mass,
                                          c.flat.envelopes.mass)
        else:
            assert c.flat.envelopes is None
    assert _oracle(clone, qs, eps) == want
    assert clone.range_query_batch(list(qs), eps) == want


def test_snapshot_latest_and_retention(tmp_path):
    _, fleet = _fleet(n=60, workers=("a", "b"))
    snap = FleetSnapshotManager(tmp_path, keep=2)
    s0 = snap.save(fleet, block=True)
    s1 = snap.save(fleet, block=True)
    assert s1 == s0 + 1
    # restore() with no step follows the latest pointer
    clone = snap.restore()
    assert clone.workers == fleet.workers


def test_restore_then_resize_shrink_and_grow(tmp_path):
    """A restored clone reshards exactly like the original would have:
    the shrink path (Alg.-2 deletes + masking) and the grow/append path
    (extend_data + FlatNet.append) both preserve hit sets, and the
    accounting buckets stay monotone through restore."""
    data, fleet = _fleet(n=150, workers=("a", "b", "c"))
    qs = data[[5, 50, 95]]
    want = _oracle(fleet, qs, 2.0)
    snap = FleetSnapshotManager(tmp_path)
    step = snap.save(fleet, block=True)

    shrunk = snap.restore(step)
    b0 = shrunk.eval_count()["build"]
    shrunk.resize(["a", "b"])
    assert shrunk.eval_count()["build"] >= b0
    assert _oracle(shrunk, qs, 2.0) == want

    grown = snap.restore(step)
    grown.resize(["a", "b", "c", "d"])
    assert len(grown.workers) == 4
    assert _oracle(grown, qs, 2.0) == want
    assert grown.range_query_batch(list(qs), 2.0) == want


# -- request queue -----------------------------------------------------------


def test_request_queue_fifo_and_lifecycle():
    q = RequestQueue()
    r1 = q.submit(np.arange(3), 1.0, now=0.5)
    r2 = q.submit(np.arange(4), 2.0, now=0.7)
    assert (r1.rid, r2.rid) == (0, 1) and q.submitted == 2
    assert len(q) == 2
    assert q.take(1) == [r1]      # FIFO, bounded take
    assert q.take(10) == [r2] and len(q) == 0
    assert not r1.done
    r1.t_admit = 0.6
    r1.finish([4, 9], now=1.5)
    assert r1.done and r1.hits == [4, 9]
    assert r1.latency == pytest.approx(1.0)   # complete - submit
    assert r1.result(timeout=1) == [4, 9]


def test_poisson_schedule_deterministic():
    a = poisson_schedule(8.0, 2.0, seed=3)
    b = poisson_schedule(8.0, 2.0, seed=3)
    np.testing.assert_array_equal(a, b)
    assert (np.diff(a) >= 0).all() and (a < 2.0).all()
    assert len(a) > 0
    assert not np.array_equal(a, poisson_schedule(8.0, 2.0, seed=4))


# -- serve engine: virtual clock ---------------------------------------------


def test_continuous_batching_shares_rounds_and_stays_exact():
    """The tentpole property: overlapping requests ride SHARED merged
    rounds (total dispatches well below the one-query-at-a-time sum)
    while every hit set matches the sequential host-loop oracle."""
    data, fleet = _fleet(n=150)
    qs = [data[i] for i in range(0, 24, 2)]
    want = _oracle(fleet, qs, 2.0)

    r0 = fleet.device_stats["rounds"]
    for q in qs:
        fleet.range_query_batch([q], 2.0)
    seq_rounds = fleet.device_stats["rounds"] - r0

    eng = ServeEngine(fleet, ServeConfig(eps=2.0))
    arrivals = np.arange(len(qs), dtype=np.float64)   # qps 1, depth > 1
    reqs = eng.run_schedule(qs, arrivals)
    assert [r.hits for r in reqs] == want
    assert eng.engine_stats()["rounds"] < seq_rounds
    assert eng.engine_stats()["completed"] == len(qs)
    # every request carries its round count and full timestamp chain
    assert all(r.rounds >= 1 and r.t_admit >= arrivals[i]
               for i, r in enumerate(reqs))

    lat = eng.latency_stats()
    assert lat["n"] == len(qs)
    assert 0 < lat["p50"] <= lat["p95"] <= lat["p99"]
    assert lat["mean_rounds"] >= 1


def test_greedy_admission_parity_and_extra_rounds():
    data, fleet = _fleet(n=150)
    qs = [data[i] for i in range(0, 16, 2)]
    arrivals = np.arange(len(qs), dtype=np.float64)
    want = _oracle(fleet, qs, 2.0)

    tick = ServeEngine(fleet, ServeConfig(eps=2.0))
    reqs_t = tick.run_schedule(qs, arrivals)
    _, fleet2 = _fleet(n=150)
    greedy = ServeEngine(fleet2, ServeConfig(eps=2.0, admission="greedy"))
    reqs_g = greedy.run_schedule(qs, arrivals)

    assert [r.hits for r in reqs_t] == want
    assert [r.hits for r in reqs_g] == want
    # greedy buys newcomers a dedicated first round; it can never spend
    # FEWER dispatches than pure shared-cadence admission
    assert greedy.engine_stats()["rounds"] >= tick.engine_stats()["rounds"]


def test_max_inflight_caps_admission():
    data, fleet = _fleet(n=120)
    qs = [data[i] for i in range(8)]
    eng = ServeEngine(fleet, ServeConfig(eps=2.0, max_inflight=2))
    for i, q in enumerate(qs):
        eng.submit(q, now=0.0)
    peak = 0
    t = 0.0
    while eng._engine.active or len(eng.queue):
        eng.tick(now=t)
        peak = max(peak, len(eng._inflight))
        t += 1.0
    assert peak <= 2
    assert [r.hits for r in eng.completed] == _oracle(fleet, qs, 2.0)


def test_mid_load_snapshot_swap_resize_zero_downtime(tmp_path):
    """A resize() mid-schedule goes snapshot -> restore clone -> reshard
    off-path -> swap at a round boundary: ZERO failed or mismatched
    requests, in-flight requests finish on the fleet that admitted them,
    post-swap requests serve from the new worker set."""
    data, fleet = _fleet(n=150)
    qs = [data[i] for i in range(0, 24, 2)]
    want = _oracle(fleet, qs, 2.0)
    eng = ServeEngine(fleet, ServeConfig(eps=2.0, snapshot_dir=tmp_path))
    arrivals = np.arange(len(qs), dtype=np.float64)
    reqs = eng.run_schedule(qs, arrivals, resize_at=5.0,
                            resize_to=["a", "b"])
    assert all(r.done for r in reqs)
    assert [r.hits for r in reqs] == want
    assert eng.swaps == 1
    assert eng.fleet.workers == ["a", "b"]
    # the swapped-in fleet keeps serving exactly
    post = eng.run_schedule(qs[:3], [0.0, 0.0, 0.0])
    assert [r.hits for r in post] == want[:3]


# -- serve engine: wall clock ------------------------------------------------


def test_wall_clock_thread_and_loadgen():
    data, fleet = _fleet(n=90)
    qs = [data[i] for i in range(6)]
    want = _oracle(fleet, qs, 2.0)
    eng = ServeEngine(fleet, ServeConfig(eps=2.0)).start()
    try:
        # direct submits resolve through Request.result()
        direct = [eng.submit(q) for q in qs[:2]]
        assert [r.result(timeout=30) for r in direct] == want[:2]
        # open-loop Poisson load drains through the same engine
        load = OpenLoopLoadGen(eng, qs, qps=200.0, seed=0).start()
        reqs = load.join(timeout=30)
    finally:
        eng.close(drain=True)
    assert [r.hits for r in reqs] == want
    assert eng.engine_stats()["completed"] == len(qs) + 2
    assert threading.active_count() >= 1   # thread shut down cleanly
    assert eng._thread is None


# -- config / facade wiring --------------------------------------------------


def test_serve_config_validation():
    with pytest.raises(ValueError, match="max_inflight"):
        ServeConfig(max_inflight=0)
    with pytest.raises(ValueError, match="admission"):
        ServeConfig(admission="eager")


def test_retrieval_config_serve_fields_round_trip_and_validate():
    from repro.retrieval import RetrievalConfig
    cfg = RetrievalConfig("levenshtein", execution="fleet", workers=2,
                          serve_max_inflight=8, serve_admission="greedy",
                          serve_snapshot_dir="/tmp/snaps")
    back = RetrievalConfig.from_json(cfg.to_json())
    assert back.serve_max_inflight == 8
    assert back.serve_admission == "greedy"
    assert back.serve_snapshot_dir == "/tmp/snaps"
    with pytest.raises(ValueError, match="serve_max_inflight"):
        RetrievalConfig("levenshtein", serve_max_inflight=0)
    with pytest.raises(ValueError, match="serve_admission"):
        RetrievalConfig("levenshtein", serve_admission="eager")


def test_facade_serve_builds_engine_fleet_only():
    from repro.retrieval import RetrievalConfig, Retriever
    data = proteins(80, seed=0)
    r = Retriever.build(
        RetrievalConfig("levenshtein", execution="fleet", workers=2,
                        serve_max_inflight=4, serve_admission="greedy"),
        data)
    eng = r.serve(eps=1.5)
    assert isinstance(eng, ServeEngine)
    assert eng.config.eps == 1.5
    assert eng.config.max_inflight == 4
    assert eng.config.admission == "greedy"
    reqs = eng.run_schedule([data[0]], [0.0])
    assert reqs[0].hits == r.batch(data[:1]).via("host").range(1.5).hits[0]

    host = Retriever.build(RetrievalConfig("levenshtein"), data)
    with pytest.raises(ValueError, match="fleet"):
        host.serve()
