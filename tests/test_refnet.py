"""Reference net / cover tree / MV index: invariants, correctness vs linear
scan, deletion, num_max capping, space model."""

import numpy as np
import pytest

from repro.core.counter import CountedDistance
from repro.core.covertree import CoverTree
from repro.core.refindex import MVReferenceIndex
from repro.core.refnet import ReferenceNet
from repro.distances import get

RNG = np.random.default_rng(42)


def _motif_strings(n, l=10, alphabet=20, n_motifs=12, mut=0.15, rng=RNG):
    motifs = rng.integers(0, alphabet, size=(n_motifs, l))
    data = motifs[rng.integers(0, n_motifs, n)]
    m = rng.random((n, l)) < mut
    return np.where(m, rng.integers(0, alphabet, size=(n, l)), data)


def _trajectories(n, l=10, rng=RNG):
    steps = rng.normal(scale=0.3, size=(n, l, 2))
    base = rng.normal(scale=2.0, size=(n, 1, 2))
    return np.cumsum(steps, axis=1) + base


CASES = [
    ("levenshtein", _motif_strings, 1.0),
    ("erp", _trajectories, 0.5),
    ("frechet", _trajectories, 0.25),
]


@pytest.mark.parametrize("dist_name,gen,eps_prime", CASES)
@pytest.mark.parametrize("tight", [False, True])
def test_refnet_range_query_matches_linear_scan(dist_name, gen, eps_prime, tight):
    data = gen(200)
    dist = get(dist_name)
    net = ReferenceNet(dist, data, eps_prime=eps_prime,
                       tight_bounds=tight).build()
    net.check_invariants()
    naive = CountedDistance(dist, data)
    for eps_frac in [0.5, 2.0, 6.0]:
        eps = eps_prime * eps_frac
        for t in range(3):
            q = data[RNG.integers(0, len(data))]
            got = net.range_query(q, eps)
            want = sorted(np.nonzero(
                naive.eval(q, np.arange(len(data))) <= eps)[0].tolist())
            assert got == want


@pytest.mark.parametrize("dist_name,gen,eps_prime", CASES[:2])
def test_covertree_matches_linear_scan(dist_name, gen, eps_prime):
    data = gen(150)
    dist = get(dist_name)
    ct = CoverTree(dist, data, eps_prime=eps_prime).build()
    ct.check_invariants()
    naive = CountedDistance(dist, data)
    q = data[3]
    eps = 3 * eps_prime
    got = ct.range_query(q, eps)
    want = sorted(np.nonzero(
        naive.eval(q, np.arange(len(data))) <= eps)[0].tolist())
    assert got == want


def test_mv_index_matches_linear_scan():
    data = _motif_strings(150)
    dist = get("levenshtein")
    mv = MVReferenceIndex(dist, data, n_refs=5).build()
    naive = CountedDistance(dist, data)
    q = data[7]
    got = mv.range_query(q, 3.0)
    want = sorted(np.nonzero(
        naive.eval(q, np.arange(len(data))) <= 3.0)[0].tolist())
    assert got == want
    assert mv.stats()["table_entries"] == 5 * len(data)


def test_refnet_rejects_non_metric():
    data = _trajectories(10)
    with pytest.raises(ValueError, match="not a metric"):
        ReferenceNet(get("dtw"), data)


def test_num_max_caps_parents():
    data = _motif_strings(300, mut=0.05)  # dense clusters -> many parents
    dist = get("levenshtein")
    un = ReferenceNet(dist, data, eps_prime=1.0).build()
    capped = ReferenceNet(dist, data, eps_prime=1.0, num_max=3).build()
    capped.check_invariants()
    assert capped.stats()["max_parents"] <= 3
    assert capped.stats()["n_list_entries"] <= un.stats()["n_list_entries"]
    # capping must not break correctness
    naive = CountedDistance(dist, data)
    q = data[11]
    want = sorted(np.nonzero(
        naive.eval(q, np.arange(len(data))) <= 2.0)[0].tolist())
    assert capped.range_query(q, 2.0) == want


def test_space_is_linear():
    """Paper fig. 5: node count and list entries grow linearly."""
    dist = get("levenshtein")
    sizes = [100, 200, 400]
    entries = []
    for n in sizes:
        data = _motif_strings(n)
        net = ReferenceNet(dist, data, eps_prime=1.0).build()
        s = net.stats()
        assert s["n_objects"] == n
        entries.append(s["n_list_entries"])
    # list entries per object stay bounded (linear space, paper §6)
    ratios = [e / n for e, n in zip(entries, sizes)]
    assert max(ratios) < 8.0
    assert max(ratios) / min(ratios) < 2.5


def test_deletion_preserves_structure():
    data = _motif_strings(120)
    dist = get("levenshtein")
    net = ReferenceNet(dist, data, eps_prime=1.0).build()
    naive = CountedDistance(dist, data)
    drop = [i for i in [5, 17, 33, 60, 99] if i != net.root]
    for i in drop:
        net.delete(i)
    q = data[2]
    keep = np.array([i for i in range(len(data)) if i not in drop])
    want = sorted(int(i) for i in keep[
        naive.eval(q, keep) <= 2.0])
    assert net.range_query(q, 2.0) == want


def test_pruning_beats_mv_at_equal_space():
    """Paper §8.2 headline: RN prunes better than MV with comparable space."""
    data = _motif_strings(400)
    dist = get("levenshtein")
    net = ReferenceNet(dist, data, eps_prime=1.0, num_max=5,
                       tight_bounds=True).build()
    mv = MVReferenceIndex(dist, data, n_refs=5).build()
    rn_evals, mv_evals = 0, 0
    for t in range(5):
        q = data[RNG.integers(0, len(data))]
        net.counter.reset()
        net.range_query(q, 2.0)
        rn_evals += net.counter.count
        mv.counter.reset()
        mv.range_query(q, 2.0)
        mv_evals += mv.counter.count
    assert rn_evals < mv_evals
