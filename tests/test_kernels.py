"""Pallas kernels (interpret mode) vs pure-jnp/ numpy oracles.

Per the deliverable: each kernel is swept over shapes and dtypes and
assert_allclose'd against the ref.py oracle.
"""

import numpy as np
import pytest

from repro.distances.oracles import ORACLES
from repro.kernels import ops

RNG = np.random.default_rng(7)

SHAPES = [
    (1, 4, 4, 1),
    (3, 8, 8, 2),
    (8, 16, 16, 4),
    (5, 20, 20, 2),   # paper window size l = 20
    (7, 9, 17, 3),    # rectangular: query segment vs window
    (16, 33, 20, 1),
    (4, 20, 24, 2),   # lambda_0-shifted segment lengths
]


def _gen(mode, B, Lx, Ly, d, dtype):
    if mode == "lev":
        return (RNG.integers(0, 7, size=(B, Lx)),
                RNG.integers(0, 7, size=(B, Ly)))
    xs = RNG.normal(size=(B, Lx, d)).astype(dtype)
    ys = RNG.normal(size=(B, Ly, d)).astype(dtype)
    return xs, ys


@pytest.mark.parametrize("mode", list(ops.MODES))
@pytest.mark.parametrize("shape", SHAPES)
def test_wavefront_kernel_matches_ref(mode, shape):
    B, Lx, Ly, d = shape
    xs, ys = _gen(mode, B, Lx, Ly, d, np.float32)
    got = np.asarray(ops.wavefront(xs, ys, mode, interpret=True))
    want = np.asarray(ops.wavefront_ref(xs, ys, mode))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", list(ops.MODES))
def test_wavefront_kernel_matches_numpy_oracle(mode):
    B, Lx, Ly, d = 6, 11, 13, 2
    xs, ys = _gen(mode, B, Lx, Ly, d, np.float32)
    got = np.asarray(ops.wavefront(xs, ys, mode, interpret=True))
    oname = {"dtw": "dtw", "erp": "erp", "dfd": "frechet",
             "lev": "levenshtein"}[mode]
    want = np.array([ORACLES[oname](xs[b], ys[b]) for b in range(B)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_wavefront_kernel_dtypes(dtype):
    B, L = 4, 8
    if np.issubdtype(dtype, np.integer):
        xs = RNG.integers(-3, 3, size=(B, L, 2)).astype(dtype)
        ys = RNG.integers(-3, 3, size=(B, L, 2)).astype(dtype)
    else:
        xs = RNG.normal(size=(B, L, 2)).astype(dtype)
        ys = RNG.normal(size=(B, L, 2)).astype(dtype)
    got = np.asarray(ops.wavefront(xs, ys, "dtw", interpret=True))
    want = np.asarray(ops.wavefront_ref(
        np.asarray(xs, np.float32), np.asarray(ys, np.float32), "dtw"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_b", [1, 4, 16])
def test_wavefront_kernel_block_sizes(block_b):
    """Grid/BlockSpec batch tiling must not change results (incl. padding)."""
    B, L = 10, 12
    xs = RNG.normal(size=(B, L, 2)).astype(np.float32)
    ys = RNG.normal(size=(B, L, 2)).astype(np.float32)
    got = np.asarray(ops.wavefront(xs, ys, "erp", block_b=block_b,
                                   interpret=True))
    want = np.asarray(ops.wavefront_ref(xs, ys, "erp"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 1, 3), (16, 16, 8), (37, 51, 19),
                                   (128, 128, 64), (130, 5, 33)])
def test_pairwise_l2_kernel(shape):
    M, N, d = shape
    x = RNG.normal(size=(M, d)).astype(np.float32)
    y = RNG.normal(size=(N, d)).astype(np.float32)
    got = np.asarray(ops.pairwise_l2(x, y, interpret=True))
    want = np.asarray(ops.pairwise_l2_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 128), (128, 32)])
def test_pairwise_l2_tilings(bm, bn):
    x = RNG.normal(size=(40, 12)).astype(np.float32)
    y = RNG.normal(size=(70, 12)).astype(np.float32)
    got = np.asarray(ops.pairwise_l2(x, y, bm=bm, bn=bn, interpret=True))
    want = np.asarray(ops.pairwise_l2_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
