"""Distance layer: batched wavefront engine vs row-major numpy oracles,
metric axioms, and the paper's consistency property (Def. 1)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tests skip, deterministic ones still run
    HAVE_HYPOTHESIS = False

from repro.core.consistency import check_consistency
from repro.distances import base, get, names
from repro.distances.oracles import ORACLES

RNG = np.random.default_rng(1234)
ALIGN = ["dtw", "erp", "frechet", "levenshtein"]
ALL = ["euclidean", "hamming"] + ALIGN


def _rand_pair(name, lx, ly, d=2, rng=RNG):
    dist = get(name)
    if not dist.variable_length:
        ly = lx
    if dist.string:
        return rng.integers(0, 6, size=(lx,)), rng.integers(0, 6, size=(ly,))
    return (rng.normal(size=(lx, d)).astype(np.float32),
            rng.normal(size=(ly, d)).astype(np.float32))


@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("lx,ly", [(1, 1), (3, 9), (8, 8), (13, 5), (20, 20)])
def test_matches_oracle(name, lx, ly):
    x, y = _rand_pair(name, lx, ly)
    got = float(get(name).pair(x, y))
    want = ORACLES[{"frechet": "frechet"}.get(name, name)](x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ALIGN)
def test_batch_matches_pairs(name):
    dist = get(name)
    B, L = 16, 10
    if dist.string:
        xs = RNG.integers(0, 5, size=(B, L))
        ys = RNG.integers(0, 5, size=(B, L))
    else:
        xs = RNG.normal(size=(B, L, 3)).astype(np.float32)
        ys = RNG.normal(size=(B, L, 3)).astype(np.float32)
    got = np.asarray(dist.batch(xs, ys))
    want = np.array([ORACLES[name if name != "frechet" else "frechet"](xs[b], ys[b])
                     for b in range(B)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ALL)
def test_identity_and_symmetry(name):
    dist = get(name)
    x, y = _rand_pair(name, 7, 7)
    assert float(dist.pair(x, x)) == pytest.approx(0.0, abs=1e-5)
    if dist.metric:
        assert float(dist.pair(x, y)) == pytest.approx(
            float(dist.pair(y, x)), rel=1e-5, abs=1e-5)


@pytest.mark.parametrize("name", [n for n in ALL if get(n).metric])
def test_triangle_inequality(name):
    dist = get(name)
    for _ in range(10):
        x, y = _rand_pair(name, 6, 8)
        _, z = _rand_pair(name, 6, 7)
        dxy = float(dist.pair(x, y))
        dxz = float(dist.pair(x, z))
        dzy = float(dist.pair(z, y))
        assert dxy <= dxz + dzy + 1e-4


def test_dtw_violates_triangle_inequality_exists():
    """The paper's running point: DTW is not a metric.  Exhibit a violation."""
    d = get("dtw")
    x = np.array([[0.0], [0.0]], np.float32)
    y = np.array([[1.0], [1.0]], np.float32)
    z = np.array([[0.0], [1.0]], np.float32)
    # d(x,y)=2 but d(x,z)+d(z,y) = 1+1... need strict violation; classic one:
    a = np.array([[0.0]], np.float32)
    b = np.array([[1.0], [1.0], [1.0]], np.float32)
    c = np.array([[0.0], [1.0]], np.float32)
    dab = float(d.pair(a, b))
    dac = float(d.pair(a, c))
    dcb = float(d.pair(c, b))
    assert dab > dac + dcb  # 3 > 1 + 0? -> 3 > 1; violation
    assert not d.metric


def test_registry_flags():
    assert set(names()) >= {"euclidean", "hamming", "dtw", "erp", "frechet",
                            "levenshtein"}
    with pytest.raises(ValueError):
        base.require_metric("dtw")
    assert base.require_consistent("dtw").name == "dtw"
    assert base.require_metric("erp").metric


@pytest.mark.parametrize("name", ALL)
def test_variable_length_padding_invariance(name):
    """Padding must never leak into the result."""
    dist = get(name)
    lx, ly = (5, 9) if dist.variable_length else (6, 6)
    x, y = _rand_pair(name, lx, ly)
    base_val = float(dist.pair(x, y))
    L = 16
    if dist.string:
        xp = np.full((L,), 3, np.int64); xp[:lx] = x
        yp = np.full((L,), 4, np.int64); yp[:ly] = y
    else:
        xp = np.ones((L, x.shape[1]), np.float32) * 7; xp[:lx] = x
        yp = np.ones((L, y.shape[1]), np.float32) * -7; yp[:ly] = y
    padded_val = float(dist.pair(xp, yp, lx, ly))
    np.testing.assert_allclose(padded_val, base_val, rtol=1e-5, atol=1e-5)


# --- hypothesis property tests -------------------------------------------
# Skipped (not failed) when hypothesis is absent; CI installs the dev extra
# so the full property suite runs there.

if HAVE_HYPOTHESIS:
    @st.composite
    def _string_pair(draw):
        lq = draw(st.integers(2, 7))
        lx = draw(st.integers(2, 7))
        q = draw(st.lists(st.integers(0, 3), min_size=lq, max_size=lq))
        x = draw(st.lists(st.integers(0, 3), min_size=lx, max_size=lx))
        return np.array(q), np.array(x)

    @settings(max_examples=25, deadline=None)
    @given(_string_pair())
    def test_consistency_property_levenshtein(pair):
        """Paper Def. 1 holds for Levenshtein on arbitrary short strings."""
        q, x = pair
        assert check_consistency(get("levenshtein"), q, x)

    @st.composite
    def _series_pair(draw):
        lq = draw(st.integers(2, 6))
        lx = draw(st.integers(2, 6))
        q = draw(st.lists(st.floats(-3, 3, width=32),
                          min_size=lq * 2, max_size=lq * 2))
        x = draw(st.lists(st.floats(-3, 3, width=32),
                          min_size=lx * 2, max_size=lx * 2))
        return (np.array(q, np.float32).reshape(lq, 2),
                np.array(x, np.float32).reshape(lx, 2))

    @settings(max_examples=15, deadline=None)
    @given(_series_pair())
    @pytest.mark.parametrize("name", ["erp", "frechet", "dtw"])
    def test_consistency_property_timeseries(name, pair):
        q, x = pair
        assert check_consistency(get(name), q, x)
else:
    @pytest.mark.skip(reason="hypothesis not installed (pip install .[dev])")
    def test_consistency_property_levenshtein():
        pass

    @pytest.mark.skip(reason="hypothesis not installed (pip install .[dev])")
    @pytest.mark.parametrize("name", ["erp", "frechet", "dtw"])
    def test_consistency_property_timeseries(name):
        pass
